"""yi-34b [dense]: llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig

# head_pad=16: 56 q-heads pad to 64 for TP-16 alignment (zero-masked pad
# heads, numerically exact; see EXPERIMENTS.md SPerf yi-34b iterations).
CONFIG = ModelConfig(
    name="yi-34b", family="decoder",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, head_pad=16)

"""arctic-480b [moe]: 128 experts top-2 PLUS a dense residual MLP in
parallel (Snowflake Arctic dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="decoder",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_pad=16,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True))

"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone.
24 encoder + 24 decoder layers (SeamlessM4T v2 large speech enc / text dec);
audio frontend stubbed as precomputed frame embeddings per assignment.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, input_mode="frames", rope="none")

"""rwkv6-1.6b [ssm] (Finch): attention-free, data-dependent decay.
Sub-quadratic -> long_500k runs. [arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab=65536, rope="none", block_pattern=("rwkv",), rwkv_head_dim=64)

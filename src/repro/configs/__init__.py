"""Assigned architecture configs (--arch <id>)."""
from repro.configs.base import ModelConfig, MoEConfig, ShapeConfig, SHAPES, \
    shape_applicable

_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "arctic-480b": "arctic_480b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "yi-34b": "yi_34b",
    "qwen1.5-4b": "qwen1_5_4b",
    "phi3-medium-14b": "phi3_medium_14b",
    "smollm-360m": "smollm_360m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    import importlib
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses
    cfg = get_config(arch)
    pat = cfg.block_pattern
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=8,
                                  top_k=min(moe.top_k, 2), d_ff_expert=64)
    return dataclasses.replace(
        cfg,
        n_layers=len(pat) * (2 if len(pat) == 1 else 1),
        enc_layers=min(cfg.enc_layers, 2),
        d_model=128, n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32, d_ff=256, vocab=512, moe=moe, rwkv_head_dim=32)

"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer. Sub-quadratic -> long_500k runs.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="decoder",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    block_pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba",
                   "mamba", "mamba"))

"""smollm-360m [dense]: small llama-arch. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="decoder",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152)

"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution; vision frontend stubbed
as precomputed patch embeddings per assignment. [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="decoder",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, rope="mrope", input_mode="vl")

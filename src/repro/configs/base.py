"""Architecture + run-shape configuration objects.

`ModelConfig` fully describes an architecture (one file per assigned arch in
this package). `ShapeConfig` describes an (input-shape) cell from the
assignment: train_4k / prefill_32k / decode_32k / long_500k.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    every: int = 1                # MoE at every k-th block (jamba: 2)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # "decoder" | "encdec" | "rwkv"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope: str = "rope"            # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    # hybrid block pattern, cycled over layers (jamba: attn + 7 mamba)
    block_pattern: Tuple[str, ...] = ("attn",)
    # mamba (jamba values)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # rwkv6
    rwkv_head_dim: int = 64
    # enc-dec
    enc_layers: int = 0
    # modality frontend stub: "tokens" | "frames" (audio) | "vl" (vision)
    input_mode: str = "tokens"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # pad q-heads up to a multiple of this (TP alignment; extra heads have
    # zero wq columns + zero wo rows, so outputs are exactly unchanged).
    # Only legal when the padded count stays a multiple of n_kv_heads.
    head_pad: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0 or self.n_kv_heads == 0

    @property
    def padded_heads(self) -> int:
        if not self.head_pad:
            return self.n_heads
        hp = -(-self.n_heads // self.head_pad) * self.head_pad
        assert hp % self.n_kv_heads == 0, \
            f"head padding {self.n_heads}->{hp} breaks GQA grouping " \
            f"(kv={self.n_kv_heads})"
        return hp

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def sub_quadratic(self) -> bool:
        """True when decode-state memory does not grow O(L^2)-attention-style
        with context (SSM / hybrid / linear attention)."""
        return self.family == "rwkv" or "mamba" in self.block_pattern

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += D * V  # lm head
        pattern = self.block_pattern
        n_attn_like = 0
        for i in range(self.n_layers + self.enc_layers):
            kind = pattern[i % len(pattern)]
            total += D  # block norm scale
            if kind == "attn":
                total += D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
                if self.qkv_bias:
                    total += (H + 2 * KV) * hd
                n_attn_like += 1
            elif kind == "mamba":
                Di = self.mamba_expand * D
                dt_rank = -(-D // 16)
                total += (D * 2 * Di + self.mamba_d_conv * Di
                          + Di * (dt_rank + 2 * self.mamba_d_state)
                          + dt_rank * Di + Di * self.mamba_d_state + Di
                          + Di * D)
            elif kind == "rwkv":
                total += 6 * D * D + 2 * D * F + D * F // F * 0  # tm + cm
            # ffn/moe per block (attn & mamba blocks both carry one)
            if kind != "rwkv":
                moe = self.moe
                if moe and (i % moe.every == moe.every - 1):
                    total += D * moe.n_experts  # router
                    total += moe.n_experts * 3 * D * moe.d_ff_expert
                    if moe.dense_residual:
                        total += 3 * D * F
                else:
                    total += 3 * D * F
                total += D  # ffn norm
        total += D  # final norm
        if self.family == "encdec":
            # decoder cross-attn per decoder layer
            total += self.n_layers * (D * (H * hd) + 2 * D * (KV * hd)
                                      + (H * hd) * D + D)
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.n_params()
        moe = self.moe
        dense_equiv = dataclasses.replace(self, moe=None)
        full = dense_equiv.n_params()
        # subtract the dense FFN we counted on MoE layers, add router +
        # top_k experts (+ dense residual if present)
        n_moe_layers = sum(
            1 for i in range(self.n_layers + self.enc_layers)
            if self.block_pattern[i % len(self.block_pattern)] != "rwkv"
            and (i % moe.every == moe.every - 1))
        D, F = self.d_model, self.d_ff
        full -= n_moe_layers * 3 * D * F
        full += n_moe_layers * (D * moe.n_experts
                                + moe.top_k * 3 * D * moe.d_ff_expert)
        if moe.dense_residual:
            full += n_moe_layers * 3 * D * F
        return full


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                     # train_4k / prefill_32k / decode_32k / long_500k
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (per assignment spec)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: pure full-attention arch — O(L^2) attention at "
                       "524288 is the assignment-mandated skip (DESIGN.md)")
    return True, ""

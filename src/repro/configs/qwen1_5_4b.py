"""qwen1.5-4b [dense]: QKV bias, MHA (kv == heads). [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="decoder",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab=151936, qkv_bias=True)

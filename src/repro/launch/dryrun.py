import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory/cost/roofline artifacts. MUST be the only entry point that
forces 512 host devices (smoke tests and benches see 1 device).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl   (resumable)
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import mesh as mesh_lib
from repro.launch import specs
from repro.models.decoder import RunFlags
from repro.roofline import hlo as hlo_lib
from repro.roofline import terms as terms_lib
from repro.train.step import TrainConfig


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             flags: RunFlags = None, tcfg: TrainConfig = None,
             keep_text: bool = False) -> dict:
    if tcfg is None and flags is not None:
        tcfg = TrainConfig(flags=flags)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rules = mesh_lib.default_rules(mesh, shape.kind, shape.global_batch,
                                   shape.seq_len,
                                   param_bytes=cfg.n_params() * 2.0)
    flags = flags or RunFlags()
    with mesh:
        jitted, args = specs.build_cell(cfg, shape, mesh, rules, tcfg=tcfg,
                                        flags=flags)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    # attention score tiles are VMEM-resident on the TPU target (Pallas
    # flash kernel); exclude them from HBM traffic, then add back the
    # kernel's true streamed K/V traffic analytically.
    costs = hlo_lib.analyze(text, vmem_tile=(flags.q_chunk, flags.kv_chunk,
                                             cfg.head_dim))
    # analytic Pallas-flash streaming traffic, kept as a cross-check against
    # the HLO-derived memory term (the score-tile VMEM exclusion above means
    # K/V streaming enters through operand accounting of the tile dots)
    flash_hbm = terms_lib.flash_hbm_traffic(cfg, shape, mesh, flags)
    chips = mesh.devices.size
    mf = terms_lib.model_flops(cfg, shape)
    mfa = terms_lib.model_flops_attn(cfg, shape)
    link_bw = terms_lib.DCN_BW if multi_pod else terms_lib.ICI_BW
    terms = terms_lib.compute_terms(costs.flops, costs.memory_bytes,
                                    costs.collective_bytes, chips, mf + mfa,
                                    costs.collective_counts, link_bw)
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok",
        "mesh": {"shape": list(mesh.devices.shape),
                 "axes": list(mesh.axis_names)},
        "rules": {"batch": rules.batch, "fsdp": rules.fsdp, "tp": rules.tp,
                  "seq": rules.seq},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "xla_cost": {k: cost.get(k) for k in ("flops", "bytes accessed")
                     if k in cost},
        "hlo": {
            "flops_per_dev": costs.flops,
            "bytes_per_dev": costs.memory_bytes,
            "collective_bytes_per_dev": costs.collective_bytes,
            "collective_counts": costs.collective_counts,
            "collective_bytes_by_op": costs.collective_bytes_by_op,
        },
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "bottleneck": terms.bottleneck,
            "model_flops": mf,
            "model_flops_attn": mfa,
            "flash_hbm_bytes": flash_hbm,
            "useful_ratio": terms.useful_ratio,
            "step_lower_bound_s": terms.total_s(),
            "roofline_fraction": terms.roofline_fraction(),
        },
    }
    if keep_text:
        rec["hlo_text"] = text
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    args = ap.parse_args()

    flags = RunFlags(remat=args.remat, q_chunk=args.q_chunk,
                     kv_chunk=args.kv_chunk)
    tcfg = TrainConfig(flags=flags, microbatches=args.microbatches)

    cells = []
    if args.all:
        pods = [False, True]
        if args.single_pod_only:
            pods = [False]
        if args.multi_pod_only:
            pods = [True]
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in pods:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.multi_pod)]

    done = set()
    out_path = pathlib.Path(args.out) if args.out else None
    if out_path and out_path.exists():
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["multi_pod"]))
            except json.JSONDecodeError:
                pass

    for arch, shape, mp in cells:
        key = (arch, shape, mp)
        if key in done:
            print(f"[dryrun] cached {key}", flush=True)
            continue
        print(f"[dryrun] {arch} x {shape} multi_pod={mp} ...", flush=True)
        try:
            rec = run_cell(arch, shape, mp, flags=flags, tcfg=tcfg)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        line = json.dumps(rec)
        if out_path:
            with out_path.open("a") as f:
                f.write(line + "\n")
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" bottleneck={r['bottleneck']}"
                     f" step>={r['step_lower_bound_s']:.4f}s"
                     f" frac={r['roofline_fraction']:.3f}"
                     f" compile={rec['compile_s']}s")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[dryrun] {arch} x {shape} mp={mp}: {status}{extra}",
              flush=True)


if __name__ == "__main__":
    main()

"""End-to-end training driver with checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

Fault tolerance: periodic async checkpoints; on start, resumes from the
latest step if a checkpoint exists (synthetic data is a pure function of
step, so the stream resumes exactly). A step-time watchdog flags straggler
steps (> straggler_factor x rolling median) — on real multi-host deploys
that signal feeds the controller's replace-node policy; here it logs.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.models import decoder, encdec
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.train.step import TrainConfig, train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-blocking", action="store_true",
                    help="synchronous saves (deterministic tests)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--die-at-step", type=int, default=None,
                    help="failure injection: hard-exit at this step")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                             total_steps=args.steps)
    tcfg = TrainConfig(optimizer=ocfg, microbatches=args.microbatches,
                       flags=RunFlags(remat="none"))

    key = jax.random.PRNGKey(0)
    api = encdec if cfg.family == "encdec" else decoder
    params = api.init(key, cfg)
    opt_state = adamw.init(params, ocfg)

    data = SyntheticLM(
        cfg.vocab, args.seq, args.batch,
        frames_dim=cfg.d_model if cfg.family == "encdec" else None,
        embeds_len=args.seq // 4 if cfg.input_mode == "vl" else 0,
        embeds_dim=cfg.d_model if cfg.input_mode == "vl" else None)

    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None and mgr.latest_step() is not None:
        start_step = mgr.latest_step()
        state = mgr.restore(start_step,
                            {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg, tcfg),
        donate_argnums=(0, 1))

    times = []
    losses = []
    it = data.iterator(start_step)
    for step in range(start_step, args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "encdec":
            batch["frames"] = batch["frames"].astype(jnp.bfloat16)
        if "embeds" in batch:
            batch["embeds"] = batch["embeds"].astype(jnp.bfloat16)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        times.append(dt)
        losses.append(loss)
        if len(times) > 8:
            med = float(np.median(times[-32:]))
            if dt > args.straggler_factor * med and step > start_step + 3:
                print(f"[watchdog] straggler step {step}: {dt:.3f}s "
                      f"(median {med:.3f}s)")
        if step % args.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"({dt:.3f}s/step)", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     blocking=args.ckpt_blocking)
        if args.die_at_step is not None and step == args.die_at_step:
            print(f"[train] injected failure at step {step}", flush=True)
            import os
            os._exit(42)

    if mgr is not None:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 blocking=True)
        mgr.wait()
    print(f"[train] done. first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()

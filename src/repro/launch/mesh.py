"""Production mesh construction + default sharding rules per run shape.

Production target: TPU v5e, 16x16 = 256 chips per pod; multi-pod adds a
"pod" axis across DCN (2 pods = 512 chips for the dry-run; the axis scales
to O(100) pods — nothing in the sharding is pod-count-specific).
"""
from __future__ import annotations

import jax

from repro.sharding.rules import Rules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_process_mesh(node_axis: str = "node", local_axis: str = "local"):
    """A ``(process_count, devices_per_process)`` mesh whose node axis is
    exactly the process boundary.

    Devices are ordered ``(process_index, id)`` so each mesh row is one
    process's devices — the layout ``Topology.from_mesh`` reads the
    intra/inter link split from (``derive_link`` classifies the node axis
    ``host_ipc`` and the local axis ``host_cpu`` on a multi-process CPU
    runtime). Requires every process to contribute the same device count.
    """
    import numpy as np
    devices = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    nproc = jax.process_count()
    if len(devices) % nproc:
        raise ValueError(f"{len(devices)} devices do not divide evenly "
                         f"across {nproc} processes")
    arr = np.array(devices).reshape(nproc, -1)
    for row in arr:
        owners = {d.process_index for d in row}
        if len(owners) != 1:
            raise ValueError(f"uneven devices per process: mesh row spans "
                             f"processes {sorted(owners)}")
    return jax.sharding.Mesh(arr, (node_axis, local_axis))


HBM_BYTES = 16e9  # v5e per-chip


def default_rules(mesh, kind: str, global_batch: int, seq_len: int,
                  param_bytes: float = 0.0) -> Rules:
    """Pick the parallelism layout for a run shape.

    train/prefill: batch over (pod, data), FSDP over data, TP over model.
    decode:        TP-resident weights (NO ZeRO-3: re-gathering params every
                   token is the latency killer the baseline sweep exposed)
                   whenever params/TP fit in HBM; batch over (pod, data);
                   long-context (batch too small) switches to context
                   parallelism — KV sequence over data.
    """
    axes = mesh.axis_names
    pod = ("pod",) if "pod" in axes else ()
    dp = pod + (("data",) if "data" in axes else ())
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp_size = mesh.shape["model"] if "model" in axes else 1
    if kind == "decode":
        # keep weights resident if the TP shard fits alongside caches
        fsdp = () if (param_bytes and
                      param_bytes / tp_size < 0.75 * HBM_BYTES) else ("data",)
        if global_batch < dp_size:
            # context parallelism: shard the KV cache sequence over data
            return Rules(batch=pod if global_batch % max(
                [mesh.shape[a] for a in pod] + [1]) == 0 and pod else (),
                fsdp=fsdp, tp="model", seq="data")
        return Rules(batch=dp, fsdp=fsdp, tp="model", seq=None)
    return Rules(batch=dp, fsdp=("data",), tp="model", seq=None)

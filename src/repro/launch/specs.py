"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

Nothing here allocates device memory: params/optimizer/batch/caches are all
abstract (jax.eval_shape), so the 480B-parameter cells lower and compile on
a single CPU host.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decoder, encdec
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.sharding import rules as R
from repro.train.step import TrainConfig, train_step


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _shard_tree(shapes, logical, rules, mesh):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(lg, s):
        spec = R.spec_for(lg, s.shape, rules, mesh_shape)
        return _sds(s.shape, s.dtype, NamedSharding(mesh, spec))

    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(one, logical, shapes, is_leaf=is_leaf)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules, mesh):
    """Abstract train batch for one global step."""
    B, S = shape.global_batch, shape.seq_len
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    tok_spec = NamedSharding(mesh, R.spec_for(("batch", None), (B, S),
                                              rules, mesh_shape))
    out = {}
    if cfg.family == "encdec":
        # seq budget split between encoder frames and decoder tokens
        out["frames"] = _sds((B, S // 2, cfg.d_model), jnp.bfloat16,
                             NamedSharding(mesh, R.spec_for(
                                 ("batch", None, None), (B, S // 2,
                                                         cfg.d_model),
                                 rules, mesh_shape)))
        out["tokens"] = _sds((B, S // 2), jnp.int32, tok_spec)
        out["labels"] = _sds((B, S // 2), jnp.int32, tok_spec)
        return out
    if cfg.input_mode == "vl":
        # 25% of the context is stub patch embeddings
        n_patch = S // 4
        n_text = S - n_patch
        out["embeds"] = _sds((B, n_patch, cfg.d_model), jnp.bfloat16,
                             NamedSharding(mesh, R.spec_for(
                                 ("batch", None, None),
                                 (B, n_patch, cfg.d_model), rules,
                                 mesh_shape)))
        out["tokens"] = _sds((B, n_text), jnp.int32, tok_spec)
        out["labels"] = _sds((B, n_text), jnp.int32, tok_spec)
        return out
    out["tokens"] = _sds((B, S), jnp.int32, tok_spec)
    out["labels"] = _sds((B, S), jnp.int32, tok_spec)
    return out


def model_api(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else decoder


def param_specs(cfg: ModelConfig, rules, mesh):
    api = model_api(cfg)
    shapes = jax.eval_shape(partial(api.init, cfg=cfg, mesh=mesh,
                                    rules=rules), jax.random.PRNGKey(0))
    return _shard_tree(shapes, api.logical(cfg), rules, mesh)


def opt_specs(cfg: ModelConfig, params_sds, rules, mesh, ocfg):
    api = model_api(cfg)
    shapes = jax.eval_shape(partial(adamw.init, cfg=ocfg), params_sds)
    logical = adamw.state_logical(api.logical(cfg), ocfg)
    return _shard_tree(shapes, logical, rules, mesh)


def _kv_sharding(cfg, rules, mesh, stacked: bool):
    from repro.layers.attention import cache_pspec
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = cache_pspec(cfg, rules, mesh_shape)
    if stacked:
        spec = jax.sharding.PartitionSpec(None, *spec)
    return NamedSharding(mesh, spec)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, rules, mesh):
    api = model_api(cfg)
    kv_shd = _kv_sharding(cfg, rules, mesh, stacked=True)
    if cfg.family == "encdec":
        shapes = jax.eval_shape(partial(encdec.init_cache, cfg, batch,
                                        max_len))
        return jax.tree.map(lambda s: _sds(s.shape, s.dtype, kv_shd), shapes)
    shapes = jax.eval_shape(partial(decoder.init_cache, cfg, batch, max_len))
    logical = decoder.cache_logical(cfg)
    out = _shard_tree(shapes, logical, rules, mesh)
    # attention KV caches use the dedicated pspec (context-parallel rules)
    for name, sub in out.items():
        j = int(name[3:])
        if cfg.block_pattern[j % len(cfg.block_pattern)] == "attn":
            out[name] = jax.tree.map(
                lambda s: _sds(s.shape, s.dtype, kv_shd), sub)
    return out


# ---------------------------------------------------------------------------
# step functions per cell kind
# ---------------------------------------------------------------------------


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                     tcfg: TrainConfig):
    params = param_specs(cfg, rules, mesh)
    opt = opt_specs(cfg, params, rules, mesh, tcfg.optimizer)
    batch = batch_specs(cfg, shape, rules, mesh)

    def fn(p, o, b):
        return train_step(p, o, b, cfg, tcfg, rules=rules, mesh=mesh)

    shardings = jax.tree.map(lambda s: s.sharding, (params, opt, batch))
    jitted = jax.jit(fn, in_shardings=shardings,
                     out_shardings=(shardings[0], shardings[1], None),
                     donate_argnums=(0, 1))
    return jitted, (params, opt, batch)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                       flags: RunFlags):
    B, S = shape.global_batch, shape.seq_len
    params = param_specs(cfg, rules, mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    if cfg.family == "encdec":
        frames = _sds((B, S, cfg.d_model), jnp.bfloat16,
                      NamedSharding(mesh, R.spec_for(
                          ("batch", None, None), (B, S, cfg.d_model),
                          rules, mesh_shape)))

        def fn(p, fr):
            enc_out = encdec.encode(p, fr, cfg, rules=rules, mesh=mesh,
                                    flags=flags)
            return enc_out, encdec.cross_cache(p, enc_out, cfg)
        jitted = jax.jit(fn, in_shardings=jax.tree.map(
            lambda s: s.sharding, (params, frames)))
        return jitted, (params, frames)

    tokens = _sds((B, S), jnp.int32,
                  NamedSharding(mesh, R.spec_for(("batch", None), (B, S),
                                                 rules, mesh_shape)))
    caches = cache_specs(cfg, B, S, rules, mesh)

    def fn(p, tok, c):
        logits, _, new_c = decoder.forward(p, tok, cfg, rules=rules,
                                           mesh=mesh, flags=flags, caches=c)
        return logits[:, -1:], new_c

    shardings = jax.tree.map(lambda s: s.sharding, (params, tokens, caches))
    jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=(2,))
    return jitted, (params, tokens, caches)


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                      flags: RunFlags):
    """serve_step: one new token against a seq_len KV cache."""
    B, S = shape.global_batch, shape.seq_len
    params = param_specs(cfg, rules, mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    tok = _sds((B, 1), jnp.int32,
               NamedSharding(mesh, R.spec_for(("batch", None), (B, 1),
                                              rules, mesh_shape)))
    idx = _sds((), jnp.int32, NamedSharding(mesh, R.spec_for((), (), rules,
                                                             mesh_shape)))
    if cfg.family == "encdec":
        caches = cache_specs(cfg, B, S, rules, mesh)
        xkv_shapes = jax.eval_shape(
            lambda: {"k": jnp.zeros((cfg.n_layers, B, S, cfg.n_kv_heads,
                                     cfg.head_dim), jnp.bfloat16),
                     "v": jnp.zeros((cfg.n_layers, B, S, cfg.n_kv_heads,
                                     cfg.head_dim), jnp.bfloat16)})
        xkv_logical = {"k": (None, "batch", "seq", "kv_heads", None),
                       "v": (None, "batch", "seq", "kv_heads", None)}
        xkv = _shard_tree(xkv_shapes, xkv_logical, rules, mesh)

        def fn(p, t, c, x, i):
            return encdec.decode_forward(p, t, None, cfg, rules=rules,
                                         mesh=mesh, flags=flags, caches=c,
                                         cache_index=i, xkv=x)
        shardings = jax.tree.map(lambda s: s.sharding,
                                 (params, tok, caches, xkv, idx))
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=(2,))
        return jitted, (params, tok, caches, xkv, idx)

    caches = cache_specs(cfg, B, S, rules, mesh)

    def fn(p, t, c, i):
        logits, _, new_c = decoder.forward(p, t, cfg, rules=rules, mesh=mesh,
                                           flags=flags, caches=c,
                                           cache_index=i)
        return logits, new_c

    shardings = jax.tree.map(lambda s: s.sharding, (params, tok, caches, idx))
    jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=(2,))
    return jitted, (params, tok, caches, idx)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
               tcfg: TrainConfig = None, flags: RunFlags = None):
    flags = flags or RunFlags()
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, rules,
                                tcfg or TrainConfig(flags=flags))
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, rules, flags)
    return build_decode_cell(cfg, shape, mesh, rules, flags)

"""Multi-process SPMD backend: a local multi-controller ``jax.distributed``
runtime behind the Communicator stack.

Two pieces:

  * :mod:`repro.distributed.backend` — process-level runtime descriptor and
    the helpers ``core.runtime`` / ``core.comm`` consult so
    ``communicator(mesh)`` works unchanged whether the mesh spans one
    process or many (global-operand construction, cross-process barriers,
    rank-0 tuning-table merge, artifact stamping).
  * :mod:`repro.distributed.launch` — a launcher that spawns K coordinated
    local processes (``jax.distributed.initialize`` against a spawned
    coordinator on loopback, CPU device count per process configurable)
    and runs a user function — or re-execs an arbitrary script — under
    multi-controller SPMD.
"""
from repro.distributed.backend import (Backend, auto_initialize, barrier,
                                       current_backend, global_array,
                                       is_multiprocess, merge_tuning_table,
                                       process_count, process_rank, to_host)
from repro.distributed.launch import LaunchError, run, spawn

__all__ = [
    "Backend", "auto_initialize", "barrier", "current_backend",
    "global_array", "is_multiprocess", "merge_tuning_table",
    "process_count", "process_rank", "to_host",
    "LaunchError", "run", "spawn",
]

"""Launcher for local multi-controller SPMD: spawn K coordinated processes.

Each worker process gets, via its environment (so ordering can never go
wrong): ``XLA_FLAGS`` forcing its own host CPU device count,
``REPRO_DIST_PROCS`` / ``REPRO_DIST_RANK`` / ``REPRO_DIST_COORD`` /
``REPRO_DIST_SCRATCH`` (the contract :func:`repro.distributed.backend
.auto_initialize` reads), and ``PYTHONPATH`` including ``src/``. The
coordinator is rank 0's ``jax.distributed.initialize`` service on a free
loopback port picked by the parent.

Two entry styles:

  * :func:`run` — run a Python function under SPMD across K processes and
    collect each rank's (pickled) return value. The function must be
    module-level; functions defined in a script run as ``__main__`` are
    addressed by file path and re-imported in the worker, so guard the
    script's side effects under ``if __name__ == "__main__":``.
  * :func:`spawn` / the CLI — re-exec an arbitrary ``argv`` K times::

        python -m repro.distributed.launch --processes 2 --devices 4 -- \\
            benchmarks/measure_collectives.py --calibrate out.json

    The child script calls ``backend.auto_initialize()`` before touching
    devices; rank 0's stdout is re-printed by the parent so CSV-row
    pipelines (``benchmarks/run.py``) work unchanged.
"""
from __future__ import annotations

import os
import pathlib
import pickle
import re
import socket
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.distributed import backend as _backend

SRC = pathlib.Path(__file__).resolve().parents[2]

_FORCE_FLAG = re.compile(r"--xla_force_host_platform_device_count=\d+")


class LaunchError(RuntimeError):
    """One or more worker processes failed (message carries per-rank
    stdout/stderr tails)."""


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _worker_env(base: Dict[str, str], rank: int, processes: int,
                devices_per_process: int, coord: str,
                scratch: str) -> Dict[str, str]:
    env = dict(base)
    flags = _FORCE_FLAG.sub("", env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{int(devices_per_process)} " + flags).strip()
    env[_backend.ENV_PROCS] = str(int(processes))
    env[_backend.ENV_RANK] = str(int(rank))
    env[_backend.ENV_COORD] = coord
    env[_backend.ENV_SCRATCH] = scratch
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    return env


def spawn(argv: Sequence[str], processes: int = 2,
          devices_per_process: int = 4, *, timeout: float = 900.0,
          env: Optional[Dict[str, str]] = None,
          scratch: Optional[str] = None) -> List[str]:
    """Run ``argv`` in ``processes`` coordinated workers; return each
    rank's stdout (rank order). Raises :class:`LaunchError` with per-rank
    output tails if any worker exits nonzero or the deadline passes."""
    if int(processes) < 1:
        raise ValueError(f"processes must be >= 1, got {processes}")
    coord = f"127.0.0.1:{free_port()}"
    scratch = scratch or tempfile.mkdtemp(prefix="repro_dist_")
    base = dict(env if env is not None else os.environ)
    procs: List[subprocess.Popen] = []
    outs: List[Tuple[pathlib.Path, pathlib.Path]] = []
    for rank in range(int(processes)):
        op = pathlib.Path(scratch) / f"rank{rank}.out"
        ep = pathlib.Path(scratch) / f"rank{rank}.err"
        outs.append((op, ep))
        procs.append(subprocess.Popen(
            list(argv), env=_worker_env(base, rank, processes,
                                        devices_per_process, coord, scratch),
            stdout=op.open("w"), stderr=ep.open("w")))
    deadline = time.monotonic() + float(timeout)
    rcs: List[Optional[int]] = [None] * len(procs)
    try:
        for i, p in enumerate(procs):
            left = deadline - time.monotonic()
            try:
                rcs[i] = p.wait(timeout=max(0.1, left))
            except subprocess.TimeoutExpired:
                rcs[i] = None
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()

    def tail(path: pathlib.Path, n: int = 3000) -> str:
        try:
            return path.read_text()[-n:]
        except OSError:
            return "<unreadable>"

    if any(rc != 0 for rc in rcs):
        detail = "\n".join(
            f"--- rank {i} rc={rc} ---\nstdout:\n{tail(op)}\n"
            f"stderr:\n{tail(ep)}"
            for i, (rc, (op, ep)) in enumerate(zip(rcs, outs))
            if rc != 0)
        raise LaunchError(
            f"{sum(rc != 0 for rc in rcs)}/{len(procs)} workers failed "
            f"(rc={rcs}, timeout={'yes' if None in rcs else 'no'})\n"
            f"{detail}")
    return [op.read_text() for op, _ in outs]


# ---------------------------------------------------------------------------
# function-payload entry: run(fn, ...) across K processes
# ---------------------------------------------------------------------------


def _fn_ref(fn) -> Dict[str, str]:
    """An importable reference to a module-level function. Functions from
    a ``__main__`` script are addressed by source path and re-imported in
    the worker under a private module name."""
    if isinstance(fn, str):
        mod, _, name = fn.partition(":")
        if not name:
            raise ValueError(f"string fn spec must be 'module:function', "
                             f"got {fn!r}")
        return {"kind": "module", "module": mod, "name": name}
    mod = getattr(fn, "__module__", None)
    name = getattr(fn, "__qualname__", getattr(fn, "__name__", None))
    if not mod or not name or "<" in name or "." in name:
        raise ValueError(
            f"run() needs a module-level function, got {fn!r}")
    if mod == "__main__":
        path = getattr(sys.modules.get("__main__"), "__file__", None)
        if not path:
            raise ValueError("cannot address a __main__ function without "
                             "a source file")
        return {"kind": "path", "path": str(pathlib.Path(path).resolve()),
                "name": name}
    return {"kind": "module", "module": mod, "name": name}


def _resolve_fn(ref: Dict[str, str]) -> Callable:
    if ref["kind"] == "module":
        import importlib
        return getattr(importlib.import_module(ref["module"]), ref["name"])
    import importlib.util
    spec = importlib.util.spec_from_file_location("_repro_dist_payload",
                                                  ref["path"])
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    return getattr(module, ref["name"])


def run(fn, *args: Any, processes: int = 2, devices_per_process: int = 4,
        kwargs: Optional[Dict[str, Any]] = None,
        timeout: float = 900.0) -> List[Any]:
    """Run ``fn(*args, **kwargs)`` under multi-controller SPMD in
    ``processes`` coordinated workers; return the per-rank results
    (rank order).

    ``fn`` is a module-level callable or a ``"module:function"`` string —
    each worker initializes ``jax.distributed`` (gloo CPU collectives),
    imports the function, calls it, and pickles its return value back.
    """
    scratch = tempfile.mkdtemp(prefix="repro_dist_")
    payload = pathlib.Path(scratch) / "payload.pkl"
    payload.write_bytes(pickle.dumps(
        {"fn": _fn_ref(fn), "args": tuple(args),
         "kwargs": dict(kwargs or {})}))
    spawn([sys.executable, "-m", "repro.distributed.launch",
           "--payload", str(payload)],
          processes=processes, devices_per_process=devices_per_process,
          timeout=timeout, scratch=scratch)
    results = []
    for rank in range(int(processes)):
        out = pathlib.Path(scratch) / f"result.rank{rank}.pkl"
        if not out.exists():
            raise LaunchError(f"rank {rank} exited 0 without a result "
                              f"payload ({out})")
        results.append(pickle.loads(out.read_bytes()))
    return results


def _worker_main(payload_path: str) -> None:
    be = _backend.auto_initialize()  # BEFORE any device access
    payload = pickle.loads(pathlib.Path(payload_path).read_bytes())
    fn = _resolve_fn(payload["fn"])
    result = fn(*payload["args"], **payload["kwargs"])
    out = (pathlib.Path(payload_path).parent
           / f"result.rank{be.process_index}.pkl")
    tmp = out.with_suffix(".tmp")
    tmp.write_bytes(pickle.dumps(result))
    tmp.replace(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="repro.distributed.launch",
        description="spawn K coordinated jax.distributed processes")
    ap.add_argument("--payload", default=None,
                    help="(internal) worker mode: run a pickled function "
                         "payload under the REPRO_DIST_* environment")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices", type=int, default=4,
                    help="CPU host devices per process")
    ap.add_argument("--timeout", type=float, default=1800.0)
    ap.add_argument("argv", nargs=argparse.REMAINDER,
                    help="script (+args) to re-exec per rank; "
                         "separate with --")
    ns = ap.parse_args(argv)
    if ns.payload:
        _worker_main(ns.payload)
        return 0
    child = [a for a in ns.argv if a != "--"]
    if not child:
        ap.error("nothing to launch: pass -- script.py [args...]")
    outs = spawn([sys.executable, *child], processes=ns.processes,
                 devices_per_process=ns.devices, timeout=ns.timeout)
    sys.stdout.write(outs[0])  # rank 0 speaks for the SPMD program
    return 0


if __name__ == "__main__":
    sys.exit(main())

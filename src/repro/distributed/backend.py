"""Process-level runtime backend for the Communicator stack.

``core.runtime`` and ``core.comm`` consult this module so the same
``communicator(mesh)`` call works whether the mesh spans one process or
many. The contract:

  * :func:`auto_initialize` bootstraps ``jax.distributed`` from the
    ``REPRO_DIST_*`` environment the launcher (:mod:`repro.distributed
    .launch`) sets — a no-op in a plain single-process run, so every
    script can call it unconditionally before touching devices. Ordering
    matters on CPU: the gloo collectives implementation must be selected
    *before* ``jax.distributed.initialize`` creates the backend client
    (the default "none" cannot run cross-process programs at all).
  * :func:`global_array` builds a global ``jax.Array`` from a host value —
    ``device_put`` only commits to this process's devices, so a
    multi-controller runtime assembles globals via
    ``jax.make_array_from_callback`` (each process contributes exactly the
    shards it owns).
  * :func:`to_host` inverts that: a fully-addressable array is a plain
    ``np.asarray``; a cross-process global is gathered with
    ``multihost_utils.process_allgather`` (every process gets the full
    value).
  * :func:`merge_tuning_table` is the rank-0 calibration merge: each rank
    writes its measured :class:`~repro.core.autotune.TuningTable` to the
    launcher's shared scratch directory, then rank 0 folds every rank's
    rows into its own table so one process can persist a single merged
    artifact.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import tempfile
from typing import Optional

import jax
import numpy as np

#: environment contract between the launcher and worker processes
ENV_PROCS = "REPRO_DIST_PROCS"
ENV_RANK = "REPRO_DIST_RANK"
ENV_COORD = "REPRO_DIST_COORD"
ENV_SCRATCH = "REPRO_DIST_SCRATCH"

_INITIALIZED = False


@dataclasses.dataclass(frozen=True)
class Backend:
    """Descriptor of the process-level runtime this process runs under.

    ``name`` is ``"single"`` for the ordinary one-process runtime and
    ``"multiprocess"`` for a multi-controller ``jax.distributed`` run;
    both values land verbatim in the calibration artifact's ``backend``
    field (schema: ``core.artifact``).
    """

    name: str
    process_count: int
    process_index: int
    coordinator: str = ""

    @property
    def multiprocess(self) -> bool:
        return self.process_count > 1


def auto_initialize() -> Backend:
    """Initialize ``jax.distributed`` from the launcher's environment.

    Reads ``REPRO_DIST_PROCS`` / ``REPRO_DIST_RANK`` / ``REPRO_DIST_COORD``;
    when absent (or one process) this is a no-op returning the single
    backend, so scripts call it unconditionally as their first
    device-touching act. Idempotent.
    """
    global _INITIALIZED
    nprocs = int(os.environ.get(ENV_PROCS, "1"))
    if nprocs <= 1:
        return current_backend()
    if not _INITIALIZED:
        rank = int(os.environ[ENV_RANK])
        coord = os.environ[ENV_COORD]
        # CPU cross-process collectives need gloo selected BEFORE the
        # backend client exists; the default "none" raises "Multiprocess
        # computations aren't implemented on the CPU backend" at run time.
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=coord,
                                   num_processes=nprocs, process_id=rank)
        _INITIALIZED = True
    return current_backend()


def current_backend() -> Backend:
    """The live backend descriptor (queries the initialized jax runtime)."""
    n = int(jax.process_count())
    if n > 1:
        return Backend("multiprocess", n, int(jax.process_index()),
                       os.environ.get(ENV_COORD, ""))
    return Backend("single", 1, 0)


def is_multiprocess() -> bool:
    return jax.process_count() > 1


def process_rank() -> int:
    return int(jax.process_index())


def process_count() -> int:
    return int(jax.process_count())


# ---------------------------------------------------------------------------
# global arrays across processes
# ---------------------------------------------------------------------------


def global_array(host, sharding):
    """Commit a host value to ``sharding`` as a global ``jax.Array``.

    Single-process: plain ``device_put``. Multi-process: ``host`` is the
    full *logical* value (every process passes the same one) and each
    process contributes the shards its devices own via
    ``jax.make_array_from_callback``.
    """
    host = np.asarray(host)
    if not is_multiprocess():
        return jax.device_put(host, sharding)
    return jax.make_array_from_callback(host.shape, sharding,
                                        lambda idx: host[idx])


def to_host(x) -> np.ndarray:
    """The full logical value of ``x`` as a numpy array on every process.

    Fully-addressable arrays (everything in a single-process runtime)
    convert directly; a cross-process global is gathered through
    ``multihost_utils.process_allgather`` first.
    """
    if not isinstance(x, jax.Array) or getattr(x, "is_fully_addressable",
                                               True):
        return np.asarray(x)
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


def barrier(name: str) -> None:
    """Block until every process reaches this point (no-op single-process).

    ``name`` must match across processes — mismatched barrier names are a
    programming error jax.distributed detects.
    """
    if not is_multiprocess():
        return
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(name)


# ---------------------------------------------------------------------------
# cross-process calibration merge
# ---------------------------------------------------------------------------


def scratch_dir() -> pathlib.Path:
    """The launcher's shared scratch directory (all ranks see one path);
    falls back to a stable per-coordinator tempdir when launched by other
    means."""
    path = os.environ.get(ENV_SCRATCH)
    if not path:
        tag = os.environ.get(ENV_COORD, "single").replace(":", "_")
        path = os.path.join(tempfile.gettempdir(), f"repro_dist_{tag}")
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    return p


def merge_tuning_table(table, tag: str = "calibrate") -> int:
    """Merge every rank's tuning-table rows into rank 0's ``table``.

    Each rank writes its table JSON to the shared scratch directory and
    synchronizes; rank 0 then folds the other ranks' rows in with
    ``TuningTable.merge(..., reduce=max)`` — ranks time the same SPMD
    plans, and a collective is only as fast as its slowest rank. Returns
    the number of ranks merged (0 in a single-process runtime, where this
    is a no-op). A trailing barrier keeps every process alive until the
    merge has read its file.
    """
    if not is_multiprocess():
        return 0
    from repro.core.autotune import TuningTable
    rank, nprocs = process_rank(), process_count()
    base = scratch_dir()
    mine = base / f"table.{tag}.rank{rank}.json"
    table.save(mine)
    barrier(f"merge_tuning_table/{tag}/written")
    merged = 0
    if rank == 0:
        for r in range(1, nprocs):
            other = base / f"table.{tag}.rank{r}.json"
            table.merge(TuningTable.load(other), reduce=max)
            merged += 1
    barrier(f"merge_tuning_table/{tag}/merged")
    return merged


def stamp_artifact(data: dict) -> dict:
    """Add the ``backend`` / ``process_count`` schema fields describing the
    runtime an artifact was measured under (see ``core.artifact``)."""
    be = current_backend()
    data["backend"] = be.name
    data["process_count"] = be.process_count
    return data

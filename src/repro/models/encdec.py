"""Encoder-decoder LM (SeamlessM4T-style backbone).

The audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, D) from input_specs(). The decoder
is a standard causal LM with per-layer cross-attention; decode uses a
self-attn KV cache plus cross K/V computed once from the encoder output.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.layers import attention, common, mlp
from repro.layers.common import Accum
from repro.models.decoder import RunFlags
from repro.sharding.rules import constrain


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": common.init_rmsnorm(cfg.d_model),
            "attn": attention.init(ks[0], cfg),
            "ln2": common.init_rmsnorm(cfg.d_model),
            "ffn": mlp.init(ks[1], cfg)}


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": common.init_rmsnorm(cfg.d_model),
            "attn": attention.init(ks[0], cfg),
            "lnx": common.init_rmsnorm(cfg.d_model),
            "xattn": attention.init(ks[1], cfg, cross=True),
            "ln2": common.init_rmsnorm(cfg.d_model),
            "ffn": mlp.init(ks[2], cfg)}


def init(key, cfg, mesh=None, rules=None):
    from repro.models.decoder import _vocab_padded
    Vp = _vocab_padded(cfg, mesh, rules)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg))(
        jax.random.split(ks[0], cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg))(
        jax.random.split(ks[1], cfg.n_layers))
    return {"embed": common.dense_init(ks[2], Vp, D, scale=1.0),
            "enc": enc, "dec": dec,
            "enc_norm": common.init_rmsnorm(D),
            "final_norm": common.init_rmsnorm(D),
            "lm_head": common.dense_init(ks[3], D, Vp)}


def logical(cfg):
    def stack(t):
        return jax.tree.map(lambda x: (None,) + x, t,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x))
    enc = stack({"ln1": {"scale": (None,)},
                 "attn": attention.logical_axes(cfg),
                 "ln2": {"scale": (None,)}, "ffn": mlp.logical_axes(cfg)})
    dec = stack({"ln1": {"scale": (None,)},
                 "attn": attention.logical_axes(cfg),
                 "lnx": {"scale": (None,)},
                 "xattn": attention.logical_axes(cfg, cross=True),
                 "ln2": {"scale": (None,)}, "ffn": mlp.logical_axes(cfg)})
    return {"embed": ("vocab", "fsdp"), "enc": enc, "dec": dec,
            "enc_norm": {"scale": (None,)}, "final_norm": {"scale": (None,)},
            "lm_head": ("fsdp", "vocab")}


def encode(params, frames, cfg, rules=None, mesh=None,
           flags: RunFlags = RunFlags()):
    """frames: (B, S_enc, D) stub embeddings -> (B, S_enc, D)."""
    h = constrain(frames.astype(common.Compute), ("batch", None, None),
                  rules, mesh)

    def body(h, layer):
        a, _ = attention.apply(
            layer["attn"],
            common.rmsnorm(h, layer["ln1"]["scale"], cfg.norm_eps),
            cfg, rules=rules, mesh=mesh, mode="bidir")
        h = h + a
        h = h + mlp.apply(layer["ffn"],
                          common.rmsnorm(h, layer["ln2"]["scale"],
                                         cfg.norm_eps),
                          cfg, rules=rules, mesh=mesh)
        return h, None

    fn = body
    if flags.remat != "none":
        fn = jax.checkpoint(body)
    h, _ = jax.lax.scan(fn, h, params["enc"])
    return common.rmsnorm(h, params["enc_norm"]["scale"], cfg.norm_eps)


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
        attention.init_cache(cfg, batch, max_len))


def cross_cache(params, enc_out, cfg):
    """Precompute per-layer cross K/V from the encoder output."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def one(layer):
        k = (enc_out @ layer["xattn"]["wk"]).reshape(
            enc_out.shape[0], -1, KV, hd)
        v = (enc_out @ layer["xattn"]["wv"]).reshape(
            enc_out.shape[0], -1, KV, hd)
        return {"k": k, "v": v}
    return jax.lax.map(one, params["dec"])


def decode_forward(params, tokens, enc_out, cfg, *, rules=None, mesh=None,
                   flags: RunFlags = RunFlags(), caches=None,
                   cache_index=None, xkv=None):
    """Decoder pass. Train/prefill: full tokens, enc_out given. Decode: one
    token, caches + cache_index + xkv (precomputed cross K/V) given."""
    h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain(h, ("batch", None, None), rules, mesh)
    decode = caches is not None and cache_index is not None

    def body(h, xs):
        if decode:
            layer, cache, xkv_l = xs
        else:
            layer, = xs
            cache, xkv_l = None, None
        a, nk = attention.apply(
            layer["attn"],
            common.rmsnorm(h, layer["ln1"]["scale"], cfg.norm_eps),
            cfg, rules=rules, mesh=mesh,
            mode="decode" if decode else "causal",
            cache=cache, cache_index=cache_index,
            use_flash_decode=flags.use_flash_decode)
        h = h + a
        xq = common.rmsnorm(h, layer["lnx"]["scale"], cfg.norm_eps)
        if decode:
            # cross-attn against the precomputed enc K/V
            q = (xq @ layer["xattn"]["wq"]).reshape(
                xq.shape[0], xq.shape[1], cfg.n_heads, cfg.head_dim)
            o = attention.attend_decode(q, xkv_l["k"], xkv_l["v"],
                                        xkv_l["k"].shape[1])
            x = (o.astype(h.dtype) @ layer["xattn"]["wo"])
        else:
            x, _ = attention.apply(layer["xattn"], xq, cfg, rules=rules,
                                   mesh=mesh, mode="cross",
                                   kv_source=enc_out)
        h = h + x
        h = h + mlp.apply(layer["ffn"],
                          common.rmsnorm(h, layer["ln2"]["scale"],
                                         cfg.norm_eps),
                          cfg, rules=rules, mesh=mesh)
        return h, nk

    if decode:
        def scan_body(c, xs):
            h2, nk = body(c, xs)
            return h2, nk
        h, new_caches = jax.lax.scan(scan_body, h,
                                     (params["dec"], caches, xkv))
    else:
        fn = (jax.checkpoint(lambda c, l: body(c, (l,)))
              if flags.remat != "none" else (lambda c, l: body(c, (l,))))
        h, new_caches = jax.lax.scan(fn, h, params["dec"])
        new_caches = None

    h = common.rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.dtype(flags.logits_dtype))
    logits = constrain(logits, ("batch", None, "vocab"), rules, mesh)
    return logits, new_caches


def forward_train(params, frames, tokens, cfg, *, rules=None, mesh=None,
                  flags: RunFlags = RunFlags()):
    enc_out = encode(params, frames, cfg, rules=rules, mesh=mesh, flags=flags)
    logits, _ = decode_forward(params, tokens, enc_out, cfg, rules=rules,
                               mesh=mesh, flags=flags)
    return logits, jnp.zeros((), Accum), None

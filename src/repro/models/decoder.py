"""Unified decoder LM covering dense / MoE / hybrid(Jamba) / RWKV / VLM
architectures, driven entirely by ModelConfig.block_pattern.

Layers are scanned over *pattern cycles* (one cycle = one period of
block_pattern, e.g. Jamba's [attn, mamba x7]); parameters are stacked over
cycles so the HLO stays compact for 94-layer models. Remat policy wraps the
cycle body.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers import attention, common, mamba, mlp, moe, rwkv
from repro.layers.common import Accum, Compute
from repro.sharding.rules import constrain


@dataclasses.dataclass(frozen=True)
class RunFlags:
    remat: str = "dots"            # "none" | "full" | "dots"
    use_flash_decode: bool = False
    use_mamba_kernel: bool = False
    use_rwkv_kernel: bool = False
    logits_dtype: str = "bfloat16"
    q_chunk: int = 512             # streaming-attention tile (hillclimb lever)
    kv_chunk: int = 1024


def _vocab_padded(cfg, mesh=None, rules=None):
    mult = 128
    if mesh is not None and rules is not None and rules.tp in getattr(
            mesh, "axis_names", ()):
        mult = max(mult, mesh.shape[rules.tp])
    return common.pad_vocab(cfg.vocab, mult)


def n_cycles(cfg):
    pat = cfg.block_pattern
    assert cfg.n_layers % len(pat) == 0, (cfg.n_layers, pat)
    return cfg.n_layers // len(pat)


def _block_is_moe(cfg, j):
    m = cfg.moe
    return m is not None and (j % m.every) == (m.every - 1)


def _init_block(key, cfg, kind, j):
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p = {"ln1": common.init_rmsnorm(cfg.d_model),
             "attn": attention.init(ks[0], cfg),
             "ln2": common.init_rmsnorm(cfg.d_model)}
    elif kind == "mamba":
        p = {"ln1": common.init_rmsnorm(cfg.d_model),
             "mamba": mamba.init(ks[0], cfg),
             "ln2": common.init_rmsnorm(cfg.d_model)}
    elif kind == "rwkv":
        return {"ln1": common.init_rmsnorm(cfg.d_model),
                "tm_cm": rwkv.init(ks[0], cfg),
                "ln2": common.init_rmsnorm(cfg.d_model)}
    else:
        raise ValueError(kind)
    if _block_is_moe(cfg, j):
        p["moe"] = moe.init(ks[1], cfg)
        if cfg.moe.dense_residual:
            p["ffn"] = mlp.init(ks[2], cfg)
    else:
        p["ffn"] = mlp.init(ks[2], cfg)
    return p


def _block_logical(cfg, kind, j):
    if kind == "rwkv":
        return {"ln1": {"scale": (None,)}, "tm_cm": rwkv.logical_axes(cfg),
                "ln2": {"scale": (None,)}}
    la = {"ln1": {"scale": (None,)}, "ln2": {"scale": (None,)}}
    if kind == "attn":
        la["attn"] = attention.logical_axes(cfg)
    else:
        la["mamba"] = mamba.logical_axes(cfg)
    if _block_is_moe(cfg, j):
        la["moe"] = moe.logical_axes(cfg)
        if cfg.moe.dense_residual:
            la["ffn"] = mlp.logical_axes(cfg)
    else:
        la["ffn"] = mlp.logical_axes(cfg)
    return la


def init(key, cfg, mesh=None, rules=None):
    Vp = _vocab_padded(cfg, mesh, rules)
    D = cfg.d_model
    k_emb, k_head, k_blocks = jax.random.split(key, 3)
    nc = n_cycles(cfg)

    def one_cycle(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {f"blk{j}": _init_block(ks[j], cfg, kind, j)
                for j, kind in enumerate(cfg.block_pattern)}

    groups = jax.vmap(one_cycle)(jax.random.split(k_blocks, nc))
    return {
        "embed": common.dense_init(k_emb, Vp, D, scale=1.0),
        "groups": groups,
        "final_norm": common.init_rmsnorm(D),
        "lm_head": common.dense_init(k_head, D, Vp),
    }


def logical(cfg):
    cyc = {f"blk{j}": _block_logical(cfg, kind, j)
           for j, kind in enumerate(cfg.block_pattern)}
    # prepend the stacked-cycles axis to every leaf
    cyc = jax.tree.map(lambda t: (None,) + t, cyc,
                       is_leaf=lambda x: isinstance(x, tuple) and all(
                           isinstance(e, (str, type(None))) for e in x))
    return {"embed": ("vocab", "fsdp"), "groups": cyc,
            "final_norm": {"scale": (None,)}, "lm_head": ("fsdp", "vocab")}


# ---------------------------------------------------------------------------
# caches / states
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, mesh=None, rules=None):
    """Stacked (over cycles) per-block decode state."""
    nc = n_cycles(cfg)

    def one(j, kind):
        if kind == "attn":
            return attention.init_cache(cfg, batch, max_len)
        if kind == "mamba":
            return mamba.init_state(cfg, batch)
        if kind == "rwkv":
            return rwkv.init_state(cfg, batch)
        raise ValueError(kind)

    def stack(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (nc,) + x.shape),
                            tree)
    return {f"blk{j}": stack(one(j, kind))
            for j, kind in enumerate(cfg.block_pattern)}


def cache_logical(cfg):
    out = {}
    for j, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            la = attention.cache_logical()
        elif kind == "mamba":
            la = mamba.state_logical()
        else:
            la = rwkv.state_logical()
        out[f"blk{j}"] = jax.tree.map(
            lambda t: (None,) + t, la,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _run_block(blk, kind, j, h, cfg, rules, mesh, flags, cache, cache_index,
               positions, positions3):
    """Returns (h, aux, new_cache)."""
    aux = None
    new_cache = None
    mode = "decode" if cache is not None and cache_index is not None else \
        "causal"
    if kind == "attn":
        a, nk = attention.apply(
            blk["attn"], common.rmsnorm(h, blk["ln1"]["scale"], cfg.norm_eps),
            cfg, rules=rules, mesh=mesh, mode=mode,
            positions=positions, positions3=positions3,
            cache=cache, cache_index=cache_index,
            use_flash_decode=flags.use_flash_decode,
            q_chunk=flags.q_chunk, kv_chunk=flags.kv_chunk)
        h = h + a
        new_cache = nk
    elif kind == "mamba":
        a, ns = mamba.apply(
            blk["mamba"], common.rmsnorm(h, blk["ln1"]["scale"], cfg.norm_eps),
            cfg, rules=rules, mesh=mesh, state=cache,
            use_kernel=flags.use_mamba_kernel)
        h = h + a
        new_cache = ns
    elif kind == "rwkv":
        x = common.rmsnorm(h, blk["ln1"]["scale"], cfg.norm_eps)
        st = cache
        y, shift, wkv_s = rwkv.time_mix(
            blk["tm_cm"]["tm"], x, cfg,
            state_shift=None if st is None else st["tm_shift"],
            state_wkv=None if st is None else st["wkv"],
            rules=rules, mesh=mesh, use_kernel=flags.use_rwkv_kernel)
        h = h + y
        x2 = common.rmsnorm(h, blk["ln2"]["scale"], cfg.norm_eps)
        y2, shift2 = rwkv.channel_mix(
            blk["tm_cm"]["cm"], x2, cfg,
            state_shift=None if st is None else st["cm_shift"])
        h = h + y2
        if st is not None:
            new_cache = {"tm_shift": shift, "wkv": wkv_s, "cm_shift": shift2}
        return h, aux, new_cache
    # ffn / moe sub-block (attn & mamba kinds)
    x2 = common.rmsnorm(h, blk["ln2"]["scale"], cfg.norm_eps)
    if "moe" in blk:
        f, aux = moe.apply(blk["moe"], x2, cfg, rules=rules, mesh=mesh)
        if "ffn" in blk:  # arctic dense residual in parallel
            f = f + mlp.apply(blk["ffn"], x2, cfg, rules=rules, mesh=mesh)
    else:
        f = mlp.apply(blk["ffn"], x2, cfg, rules=rules, mesh=mesh)
    h = h + f
    return h, aux, new_cache


def _cycle(h, group, cfg, rules, mesh, flags, caches, cache_index, positions,
           positions3):
    aux_total = jnp.zeros((), Accum)
    new_caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        cache_j = None if caches is None else caches[f"blk{j}"]
        h, aux, nc = _run_block(group[f"blk{j}"], kind, j, h, cfg, rules,
                                mesh, flags, cache_j, cache_index, positions,
                                positions3)
        if aux is not None:
            aux_total = aux_total + aux.mean().astype(Accum)
        if nc is not None:
            new_caches[f"blk{j}"] = nc
    return h, aux_total, new_caches


def embed_apply(params, tokens, cfg, *, rules=None, mesh=None,
                embeds: Optional[jax.Array] = None):
    """The forward's embedding stage alone: token lookup (+ optional
    frontend embeds prepended). The entry segment of the backward-segmented
    train step — its VJP is the embedding-table grad bucket."""
    h = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    return constrain(h, ("batch", None, None), rules, mesh)


def _mrope_positions3(cfg, B, T, cache_index, positions3):
    if cfg.rope == "mrope" and positions3 is None:
        base = cache_index if cache_index is not None else 0
        if getattr(base, "ndim", 0):
            # per-slot decode indices: each row's positions start at its own
            # true length (continuous-batching mixed-length ticks)
            pos = jnp.arange(T)[None] + base[:, None]
        else:
            pos = jnp.broadcast_to(jnp.arange(T)[None] + base, (B, T))
        positions3 = common.text_positions3(pos)
    return positions3


def _remat_wrap(scan_body, flags: RunFlags):
    if flags.remat == "full":
        return jax.checkpoint(scan_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if flags.remat == "dots":
        return jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return scan_body


def segment_apply(params, h, cfg, lo: int, hi: int, *, rules=None,
                  mesh=None, flags: RunFlags = RunFlags(),
                  positions3: Optional[jax.Array] = None):
    """Run pattern cycles ``[lo, hi)`` of the stacked groups on hidden
    state ``h`` (training path: no caches). Returns ``(h, aux_sum)``.

    This is the forward's scan restricted to a static cycle window — the
    unit the backward-segmented train step takes a per-bucket VJP of, so
    bucket i's allreduce can start while cycles ``[0, lo)`` are still
    running backward. ``segment_apply(params, h, cfg, 0, n_cycles(cfg))``
    is the whole trunk (and is exactly what :func:`forward` runs)."""
    B, T, _ = h.shape
    positions3 = _mrope_positions3(cfg, B, T, None, positions3)
    body = partial(_cycle, cfg=cfg, rules=rules, mesh=mesh, flags=flags,
                   cache_index=None, positions=None, positions3=positions3)

    def scan_body(carry, group):
        h, aux, _ = body(carry, group, caches=None)
        return h, aux

    gslice = jax.tree.map(
        lambda g: jax.lax.slice_in_dim(g, lo, hi, axis=0), params["groups"])
    h, auxs = jax.lax.scan(_remat_wrap(scan_body, flags), h, gslice)
    return h, auxs.sum()


def head_apply(params, h, cfg, *, rules=None, mesh=None,
               flags: RunFlags = RunFlags()):
    """The forward's output stage alone: final norm + LM head. The exit
    segment of the backward-segmented train step — its VJP is the
    (final_norm, lm_head) grad bucket plus the trunk cotangent."""
    h = common.rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.dtype(flags.logits_dtype))
    return constrain(logits, ("batch", None, "vocab"), rules, mesh)


def forward(params, tokens, cfg, *, rules=None, mesh=None,
            flags: RunFlags = RunFlags(), caches=None, cache_index=None,
            embeds: Optional[jax.Array] = None,
            positions3: Optional[jax.Array] = None):
    """tokens: (B, T) int32. embeds: optional (B, T_p, D) stub-frontend
    embeddings (VLM patches / audio frames) prepended to the token stream.

    Returns (logits (B, T_total, vocab_padded), aux_loss scalar, new_caches).
    """
    h = embed_apply(params, tokens, cfg, rules=rules, mesh=mesh,
                    embeds=embeds)
    B, T, D = h.shape

    positions = None
    positions3 = _mrope_positions3(cfg, B, T, cache_index, positions3)

    body = partial(_cycle, cfg=cfg, rules=rules, mesh=mesh, flags=flags,
                   cache_index=cache_index, positions=positions,
                   positions3=positions3)

    if caches is None:
        def scan_body(carry, group):
            h = carry
            h, aux, _ = body(h, group, caches=None)
            return h, aux
        h, auxs = jax.lax.scan(_remat_wrap(scan_body, flags), h,
                               params["groups"])
        new_caches = None
        aux = auxs.sum()
    else:
        def scan_body(carry, xs):
            h = carry
            group, cache_c = xs
            h, aux, nc = body(h, group, caches=cache_c)
            return h, (aux, nc)
        h, (auxs, new_caches) = jax.lax.scan(scan_body, h,
                                             (params["groups"], caches))
        aux = auxs.sum()

    logits = head_apply(params, h, cfg, rules=rules, mesh=mesh, flags=flags)
    return logits, aux, new_caches

# The paper's primary contribution: PiP-MColl multi-object collectives,
# two-level topology, alpha-beta cost models, algorithm autotuning, and the
# version-portable cached collective runtime.
from repro.core.topology import Topology
from repro.core import compat, mcoll, costmodel, autotune, runtime

__all__ = ["Topology", "compat", "mcoll", "costmodel", "autotune", "runtime"]

# The paper's primary contribution: PiP-MColl multi-object collectives,
# two-level topology, alpha-beta cost models, and algorithm autotuning.
from repro.core.topology import Topology
from repro.core import mcoll, costmodel, autotune

__all__ = ["Topology", "mcoll", "costmodel", "autotune"]

# The paper's primary contribution: PiP-MColl multi-object collectives,
# two-level topology (with per-axis link metadata), alpha-beta cost models,
# the algorithm-selection subsystem (priors + measured tuning tables), the
# version-portable cached collective runtime, and the Communicator object
# API (blocking methods + persistent nonblocking ops) resolving algo="auto".
from repro.core.topology import Topology
from repro.core.autotune import Selector, TuningTable
from repro.core import compat, mcoll, costmodel, autotune, runtime, comm
from repro.core.comm import Communicator, PersistentOp, CollHandle, PlanSpec

__all__ = ["Topology", "Selector", "TuningTable", "compat", "mcoll",
           "costmodel", "autotune", "runtime", "comm", "Communicator",
           "PersistentOp", "CollHandle", "PlanSpec"]

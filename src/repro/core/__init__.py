# The paper's primary contribution: PiP-MColl multi-object collectives,
# two-level topology (with per-axis link metadata), alpha-beta cost models,
# the algorithm-selection subsystem (priors + measured tuning tables), and
# the version-portable cached collective runtime resolving algo="auto".
from repro.core.topology import Topology
from repro.core.autotune import Selector, TuningTable
from repro.core import compat, mcoll, costmodel, autotune, runtime

__all__ = ["Topology", "Selector", "TuningTable", "compat", "mcoll",
           "costmodel", "autotune", "runtime"]

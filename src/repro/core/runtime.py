"""Collective runtime: the single entry point for building and invoking
shard_map'd collectives.

This layer owns, for the whole codebase:

  1. **version portability** — all shard_map construction flows through
     ``repro.core.compat`` (the only module allowed to touch the raw JAX
     entry point), so a JAX API move is absorbed in one place;
  2. **wiring** — the per-collective ``body`` / ``in_specs`` / ``out_specs``
     conventions live in the declarative :data:`_WIRING` table instead of
     being re-derived at every call site;
  3. **caching** — mirroring how mpi4jax funnels every MPI primitive through
     one token-threaded dispatch layer, repeated invocations from
     training / serving / benchmark loops reuse both the built callable
     (keyed on mesh + collective + algo + kwargs) and the AOT-compiled
     executable (additionally keyed on input shape/dtype). Both caches are
     LRU-bounded (:func:`set_cache_limits`) so shape-diverse serving
     traffic cannot grow them without limit; evictions are counted in
     :class:`CacheStats`.
  4. **algorithm selection** — ``algo="auto"`` resolves through the
     selection subsystem (``repro.core.autotune``: cost-model priors +
     measured calibration) at exec-cache time, keyed on the *resolved*
     algorithm so auto and explicit callers share cache entries. The
     resolution is a full ``(algo, chunks, codec)`` plan (tuning-table key
     ``algo#cN@codec``): the chunk count and codec are normalized into the
     kwargs (and therefore the exec-cache key), ``chunk_bytes=<b>`` is
     accepted as a size-relative way to pin the chunking, and
     ``error_budget=<eps>`` gates which error-bounded codecs
     (``repro.core.compress``) auto may pick (0.0 = lossless only).

Since the Communicator API landed (``repro.core.comm``), this module is the
**cache backend**: construction, compilation and plan resolution live here;
the supported user-facing surface is ``comm.Communicator`` (one method per
collective, persistent nonblocking ops, ``comm.split`` sub-communicators).

Public API:

  * :func:`run` — execute a collective through the compiled-callable cache
    (the backend entry point ``Communicator`` methods call); ``algo="auto"``
    picks the algorithm per (topology, collective, dtype, size).
  * :func:`build` — get the cached jitted callable for a collective key.
  * :func:`compile_persistent` — AOT-compile one plan for a fixed
    shape/dtype with a pinned input sharding (the ``PersistentOp`` backend;
    entries share the exec cache, so re-initialising an op is a hit).
  * :func:`sharded` — version-portable shard_map for custom bodies (MoE
    expert-parallel dispatch, the manual train step, ad-hoc checks).
  * :func:`calibrate` — timed sweeps feeding the selector's tuning table.
  * :func:`cache_stats` / :func:`selection_stats` / :func:`clear_cache` —
    observe / reset the caches and the selector.
"""
from __future__ import annotations

import dataclasses
import inspect
import time as _time
from collections import OrderedDict
from functools import lru_cache, partial
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import autotune, compat
from repro.core import compress as _codecs
from repro.core import mcoll as _mcoll
from repro.core import telemetry as _tm
from repro.core.topology import Topology

AUTO = "auto"

# ---------------------------------------------------------------------------
# version-portable shard_map for custom bodies
# ---------------------------------------------------------------------------


def sharded(body: Callable, mesh, in_specs: Any, out_specs: Any,
            check: bool = False) -> Callable:
    """Wrap ``body`` with a version-portable shard_map over ``mesh``.

    This is the supported way to shard_map a custom body anywhere in the
    codebase; it keeps direct JAX-API references confined to ``compat``.
    """
    return compat.shard_map(body, mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=check)


# ---------------------------------------------------------------------------
# declarative wiring table: collective -> shard_map conventions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Wiring:
    """How one collective maps global arrays onto per-device bodies.

    in_mode:    "shard"     input dim0 sharded over the flat (node, local)
                            axis tuple,
                "replicate" input replicated,
                "row"       input dim0 sharded, each device's shard is one
                            leading row (the body consumes ``x[0]``).
    out_mode:   "stack"     per-device results stacked along a new dim0
                            (row d = device d's result),
                "shard"     output dim0 sharded,
                "replicate" output replicated.
    take_row0:  body consumes ``x[0]`` rather than ``x``.
    stackable:  honors ``stacked=False`` by switching out_mode to
                "replicate" (allgather's replicated-output variant).
    """

    in_mode: str
    out_mode: str
    take_row0: bool = False
    stackable: bool = False


_WIRING: Dict[str, Wiring] = {
    "allgather": Wiring("shard", "stack", stackable=True),
    "scatter": Wiring("replicate", "shard"),
    "broadcast": Wiring("replicate", "stack"),
    "allreduce": Wiring("row", "stack", take_row0=True),
    "reduce_scatter": Wiring("row", "shard", take_row0=True),
    "alltoall": Wiring("row", "stack", take_row0=True),
}


def _in_spec(mode: str, ax) -> P:
    return {"shard": P(ax), "replicate": P(None), "row": P(ax, None)}[mode]


def _out_spec(mode: str, ax) -> P:
    return {"stack": P(ax, None), "shard": P(ax), "replicate": P(None)}[mode]


def collectives() -> Tuple[str, ...]:
    return tuple(sorted(_WIRING))


def algorithms(collective: str):
    """Algorithm names registered for ``collective`` (see core.mcoll)."""
    return _mcoll.algorithms(collective)


# ---------------------------------------------------------------------------
# caches (LRU-bounded)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    build_hits: int = 0
    build_misses: int = 0
    build_evictions: int = 0
    exec_hits: int = 0
    exec_misses: int = 0
    exec_evictions: int = 0

    @property
    def exec_hit_rate(self) -> float:
        total = self.exec_hits + self.exec_misses
        return self.exec_hits / total if total else 0.0

    def reset(self) -> None:
        """Zero every counter in place (handles stay live) — per-phase
        assertions in checks start from a clean baseline instead of
        subtracting process-lifetime totals by hand."""
        self.build_hits = self.build_misses = self.build_evictions = 0
        self.exec_hits = self.exec_misses = self.exec_evictions = 0


_DEFAULT_MAX_BUILD = 256
_DEFAULT_MAX_EXEC = 1024

_BUILD_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_EXEC_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_LIMITS = {"build": _DEFAULT_MAX_BUILD, "exec": _DEFAULT_MAX_EXEC}
_STATS = CacheStats()


def cache_stats() -> CacheStats:
    return _STATS


def selection_stats() -> autotune.SelectionStats:
    """Selection counters of the default selector (the one ``algo="auto"``
    resolves through) — lives next to cache_stats for observability."""
    return autotune.default_selector().stats


def set_cache_limits(max_build: Optional[int] = None,
                     max_exec: Optional[int] = None) -> Dict[str, int]:
    """Set LRU bounds (entries) for the build/exec caches; None leaves a
    bound unchanged. Returns the active limits. Shrinking evicts oldest
    entries immediately (counted in CacheStats)."""
    if max_build is not None:
        _LIMITS["build"] = int(max_build)
    if max_exec is not None:
        _LIMITS["exec"] = int(max_exec)
    _evict(_BUILD_CACHE, "build")
    _evict(_EXEC_CACHE, "exec")
    return dict(_LIMITS)


def _evict(cache: "OrderedDict", which: str) -> None:
    limit = max(1, _LIMITS[which])
    while len(cache) > limit:
        cache.popitem(last=False)
        if which == "build":
            _STATS.build_evictions += 1
        else:
            _STATS.exec_evictions += 1


def clear_cache() -> None:
    _BUILD_CACHE.clear()
    _EXEC_CACHE.clear()
    _STATS.reset()  # in place, so handles from cache_stats() stay live


def _kw_key(kw: Dict[str, Any]) -> tuple:
    return tuple(sorted(kw.items()))


def _span_tags(topo: Topology, collective: str, algo: str,
               kw: Dict[str, Any], nbytes: Optional[int] = None
               ) -> Dict[str, Any]:
    """Telemetry tag dict for one resolved plan at a runtime boundary."""
    return _tm.plan_tags(collective, algo, int(kw.get("chunks", 1)),
                         str(kw.get("codec", "none")), topo.group or "",
                         nbytes=nbytes)


# ---------------------------------------------------------------------------
# algorithm resolution (algo="auto")
# ---------------------------------------------------------------------------


def _message_bytes(collective: str, topo: Topology, x) -> int:
    """Per-process message size in the cost model's conventions, from the
    *global* runtime operand: broadcast's operand is the per-process payload
    itself; every other collective's operand carries all ``world`` shards."""
    if collective == "broadcast":
        return max(1, int(x.nbytes))
    return max(1, int(x.nbytes) // topo.world)


@lru_cache(maxsize=None)  # one small frozenset per algorithm function
def _accepted_params(fn: Callable) -> frozenset:
    return frozenset(inspect.signature(fn).parameters)


def _filter_kwargs(fn: Callable, kw: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only kwargs the algorithm function accepts (an auto-resolved
    algorithm must not choke on another algorithm's tuning knobs)."""
    if not kw:
        return kw
    params = _accepted_params(fn)
    return {k: v for k, v in kw.items() if k in params}


def resolve_algo(topo: Topology, collective: str, algo: str, x,
                 kw: Optional[Dict[str, Any]] = None,
                 error_budget: float = 0.0,
                 selector: Optional[autotune.Selector] = None
                 ) -> Tuple[str, Dict[str, Any]]:
    """Resolve ``algo`` ("auto" -> selector (algo, chunks, codec) plan)
    for operand ``x``.

    Returns (resolved_algo, normalized_kwargs). Explicit algorithm names
    pass through untouched; chunk and codec knobs are normalized either
    way so exec-cache keys are shared between auto and explicit callers of
    the same plan:

      * ``chunk_bytes=<b>`` converts to ``chunks=ceil(payload/b)`` against
        the per-process payload of ``x`` (so one knob serves every size);
      * a chunk-capable algorithm always carries an explicit ``chunks``
        entry (default 1), and a codec-capable one an explicit ``codec``
        entry (default "none"), so the default knobs and "no kwarg" are
        one cache key;
      * ``algo="auto"`` fills ``chunks``/``codec`` from the selector's
        plan unless the caller pinned them; ``error_budget`` (also
        accepted inside ``kw``) gates which codecs the selector may pick
        (0.0 = lossless only); ``selector`` overrides the process-wide
        default (a Communicator passes its own).
    """
    kw = dict(kw or {})
    budget = kw.pop("error_budget", None)
    if budget is None:
        budget = error_budget
    nbytes = _message_bytes(collective, topo, x)
    cb = kw.pop("chunk_bytes", None)
    if cb:
        kw.setdefault("chunks", max(1, -(-nbytes // int(cb))))
    if algo != AUTO:
        try:
            fn = _mcoll.algorithm(collective, algo)
        except KeyError:
            raise ValueError(
                f"unknown algorithm {algo!r} for {collective}; one of "
                f"{_mcoll.algorithms(collective)}") from None
        if _mcoll.supports_chunks(collective, algo):
            kw["chunks"] = int(kw.get("chunks", 1))
        elif "chunks" in kw:
            # fail clearly at resolution time, not as an opaque TypeError
            # deep inside trace (the auto path filters this instead)
            raise ValueError(
                f"{collective}/{algo} does not support chunking; "
                f"chunk-capable algorithms: "
                f"{sorted(_mcoll.CHUNKED[collective]) or 'none'}")
        if _mcoll.supports_codec(collective, algo):
            cdd = str(kw.get("codec", _codecs.NONE))
            _codecs.codec(cdd)  # validate the name at resolution time
            if cdd != _codecs.NONE and not _codecs.admissible(
                    cdd, collective,
                    max(float(budget), _codecs.meta(cdd).error_bound),
                    jnp.issubdtype(x.dtype, jnp.integer)):
                # fail at resolution time with the domain reason, not as a
                # trace-time error deep inside the algorithm body
                raise ValueError(
                    f"codec {cdd!r} is not admissible for {collective} on "
                    f"dtype {x.dtype} (lossy codecs never touch integer "
                    f"payloads; integer-only codecs need integer payloads "
                    f"on non-reducing collectives)")
            kw["codec"] = cdd
        elif kw.get("codec", _codecs.NONE) != _codecs.NONE:
            raise ValueError(
                f"{collective}/{algo} does not support compression; "
                f"codec-capable algorithms: "
                f"{sorted(_mcoll.COMPRESSED[collective]) or 'none'}")
        else:
            kw.pop("codec", None)
        # plan-time kwarg validation: an unsupported knob must be a clear
        # resolution error, not a TypeError deep inside trace
        bad = set(kw) - _accepted_params(fn)
        if bad:
            raise ValueError(
                f"{collective}/{algo} got unsupported kwargs "
                f"{sorted(bad)}; accepted: "
                f"{sorted(_accepted_params(fn) - {'x', 'y', 'z', 'topo'})}")
        return algo, kw
    pinned_codec = kw.get("codec")
    if pinned_codec is not None:
        pinned_codec = str(pinned_codec)
        _codecs.codec(pinned_codec)  # validate the name before selection
        if pinned_codec != _codecs.NONE:
            if not any(_mcoll.supports_codec(collective, a)
                       for a in autotune.candidates(collective, topo)):
                raise ValueError(
                    f"{collective} has no codec-capable algorithm; "
                    f"codec={pinned_codec!r} cannot be honored")
            # pinning a lossy codec IS an accuracy contract: selection
            # must admit it even when no explicit budget was given
            budget = max(float(budget),
                         _codecs.meta(pinned_codec).error_bound)
            if not _codecs.admissible(pinned_codec, collective,
                                      float(budget),
                                      jnp.issubdtype(x.dtype, jnp.integer)):
                raise ValueError(
                    f"codec {pinned_codec!r} is not admissible for "
                    f"{collective} on dtype {x.dtype} (lossy codecs never "
                    f"touch integer payloads; integer-only codecs need "
                    f"integer payloads on non-reducing collectives)")
    sel = (selector if selector is not None
           else autotune.default_selector()).choose(
        collective, topo, nbytes, dtype=str(x.dtype),
        error_budget=float(budget))
    algo, chunks = sel.algo, sel.chunks
    if pinned_codec not in (None, _codecs.NONE) and \
            not _mcoll.supports_codec(collective, algo):
        # the selector's winner cannot carry the pinned codec (e.g. a
        # latency-regime algorithm): honor the pin by taking the cheapest
        # codec-capable plan instead of silently dropping the knob
        from repro.core import costmodel
        net = costmodel.net_for(topo)
        cnet = costmodel.codec_net(net, topo, pinned_codec)
        best = None
        for a in autotune.candidates(collective, topo):
            if not _mcoll.supports_codec(collective, a):
                continue
            try:
                c = (costmodel.optimal_chunks(collective, a, topo, nbytes,
                                              cnet)
                     if _mcoll.supports_chunks(collective, a) else 1)
                t = costmodel.plan_cost(collective, a, topo, nbytes, net,
                                        chunks=c, codec=pinned_codec).time
            except ValueError:  # implemented but not modeled (cf. choose)
                t, c = float("inf"), 1
            if best is None or t < best[0]:
                best = (t, a, c)
        # the capability pre-check above guarantees >=1 codec-capable
        # candidate, so best is always set (unmodeled ones rank last)
        _, algo, chunks = best
    kw = _filter_kwargs(_mcoll.algorithm(collective, algo), kw)
    if _mcoll.supports_chunks(collective, algo):
        kw["chunks"] = int(kw.get("chunks", chunks or 1))
    if _mcoll.supports_codec(collective, algo):
        kw["codec"] = str(kw.get("codec", sel.codec or _codecs.NONE))
    return algo, kw


# ---------------------------------------------------------------------------
# construction + compiled-callable cache
# ---------------------------------------------------------------------------


def supports_carry(collective: str, algo: str) -> bool:
    """Whether ``(collective, algo)`` can run as a carry-threaded persistent
    program: the algorithm must accept an ``err`` state operand (the
    error-feedback carry of the compressed reductions)."""
    try:
        fn = _mcoll.algorithm(collective, algo)
    except KeyError:
        return False
    return "err" in _accepted_params(fn)


def _construct(mesh, topo: Topology, collective: str, algo: str,
               stacked: bool, jit: bool, donate: bool,
               carry: bool = False, **kw) -> Callable:
    wiring = _WIRING[collective]
    fn = partial(_mcoll.algorithm(collective, algo), topo=topo, **kw)
    # shard over ALL mesh axes, not just the topology's: operands stay
    # global (dim0 spans every device of the mesh) while the algorithm
    # communicates only over topo's axes — so a sub-communicator group
    # (topo covering a subset of the mesh) runs independently per group
    # and out row d is device d's within-group result. For a topology
    # covering the whole mesh this is the same spec as before.
    ax = tuple(mesh.axis_names)
    out_mode = wiring.out_mode
    if wiring.stackable and not stacked:
        out_mode = "replicate"
    take_row0, stack_out = wiring.take_row0, out_mode == "stack"

    if carry:
        # carry-threaded variant: a second state operand rides the same
        # wiring as the payload (error-feedback residuals live at
        # device-dependent offsets, so both are "row"-sharded) and a fresh
        # state comes back next to the result — op.start(x, carry=e) ->
        # (y, new_e). Only algorithms that accept err can be built this way.
        if not (take_row0 and stack_out):
            raise ValueError(
                f"carry operand needs row-in/stack-out wiring; "
                f"{collective} is {wiring.in_mode}/{wiring.out_mode}")
        if not supports_carry(collective, algo):
            raise ValueError(
                f"{collective}/{algo} does not thread a carry (no err "
                f"state operand); carry-capable allreduce algorithms: "
                f"{[a for a in _mcoll.algorithms(collective) if supports_carry(collective, a)]}")

        def body_carry(x, e):
            y, ne = fn(x[0], err=e[0])
            return y[None], ne[None]

        spec = _in_spec(wiring.in_mode, ax)
        mapped = sharded(body_carry, mesh, in_specs=(spec, spec),
                         out_specs=(_out_spec(out_mode, ax),) * 2,
                         check=False)
        if not jit:
            return mapped
        return jax.jit(mapped, donate_argnums=(0, 1) if donate else ())

    def body(x):
        y = fn(x[0] if take_row0 else x)
        return y[None] if stack_out else y

    mapped = sharded(body, mesh, in_specs=(_in_spec(wiring.in_mode, ax),),
                     out_specs=_out_spec(out_mode, ax), check=False)
    if not jit:
        return mapped
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def build(mesh, topo: Topology, collective: str, algo: str, *,
          stacked: bool = True, jit: bool = True, donate: bool = False,
          carry: bool = False, **kw) -> Callable:
    """Build (or fetch from cache) the jitted shard_map'd callable for one
    collective key. Identical keys return the identical callable object, so
    jit's trace cache is shared across call sites.

    Key: (mesh axes/shape/devices, collective, algo, stacked, jit, donate,
    kwargs). Input shape/dtype enter at :func:`run` time via jit's own
    trace cache (and explicitly in the exec cache). ``donate=True`` donates
    the operand buffer to the computation (persistent double-buffered ops
    on backends that support aliasing).

    Input/output conventions (global arrays; D = mesh devices, G =
    ``topo.world`` — equal for a root communicator, G < D for a
    sub-communicator group, where every device's result is computed within
    its own group):
      allgather:      in (D*m, ...) sharded dim0 -> out (D, G*m, ...)
                      stacked (row d = device d's group copy) or
                      (G*m, ...) replicated when G == D.
      scatter:        in (G*m, ...) replicated   -> out (D*m, ...) sharded
                      (device d's shard = its within-group scatter share).
      broadcast:      in (m, ...) replicated     -> out (D, m, ...) stacked.
      allreduce:      in (D, m, ...) sharded dim0 -> out (D, m, ...)
                      stacked (row d = device d's group-reduced vector).
      reduce_scatter: in (D, G*s, ...) sharded dim0 -> out (D*s, ...)
                      sharded.
      alltoall:       in (D, G, s...) sharded dim0 -> out (D, G, s...)
                      sharded.
    """
    if collective not in _WIRING:
        raise ValueError(f"unknown collective {collective!r}; "
                         f"one of {collectives()}")
    if algo == AUTO:
        raise ValueError("algo='auto' resolves per input size/dtype; call "
                         "Communicator methods (or resolve_algo first)")
    # Mesh hashes/compares by axis names + device assignment, so it keys
    # the cache directly (no per-call O(n_devices) key construction). The
    # fused-codec switch changes the traced program, so it's part of the key
    # (the conformance A/B under compress.jnp_reference_paths must not hit
    # a program built with fusion on, and vice versa).
    key = (mesh, topo, collective, algo, stacked, jit, donate, carry,
           _kw_key(kw), _codecs.fused_enabled())
    hit = _BUILD_CACHE.get(key)
    if hit is not None:
        _STATS.build_hits += 1
        _BUILD_CACHE.move_to_end(key)
        return hit
    _STATS.build_misses += 1
    with _tm.span(f"build/{collective}", cat="build",
                  **(_span_tags(topo, collective, algo, kw)
                     if _tm.enabled() else {})):
        built = _construct(mesh, topo, collective, algo, stacked, jit,
                           donate, carry, **kw)
    _BUILD_CACHE[key] = built
    _evict(_BUILD_CACHE, "build")
    return built


def run(mesh, topo: Topology, name: str, algo: str, x, *,
        stacked: bool = True, error_budget: float = 0.0, **kw):
    """Execute collective ``name`` with ``algo`` on ``x`` over ``mesh``
    through the compiled-callable cache (the ``Communicator`` backend).

    The AOT-compiled executable is cached on (mesh, collective, algo, input
    shape/dtype, kwargs), so every invocation after the first with an
    identical key skips trace, lowering and compilation entirely.

    ``algo="auto"`` resolves through the selection subsystem (measured
    tuning table when calibrated, cost-model prior otherwise) before the
    cache lookup — the key carries the *resolved* plan (algorithm + chunk
    count + codec), so auto and explicit callers share compiled
    executables. ``error_budget`` lets auto pick an error-bounded codec
    plan (``core.compress``); the default 0.0 keeps resolution lossless.
    An explicit ``codec=`` kwarg pins the codec on the codec-capable
    algorithms instead.
    """
    if name not in _WIRING:  # before selector resolution, for the friendly
        raise ValueError(f"unknown collective {name!r}; "  # error either way
                         f"one of {collectives()}")
    x = global_operand(mesh, name, x)
    algo, kw = resolve_algo(topo, name, algo, x, kw,
                            error_budget=error_budget)
    return run_resolved(mesh, topo, name, algo, x, stacked=stacked, **kw)


def run_resolved(mesh, topo: Topology, name: str, algo: str, x, *,
                 stacked: bool = True, **kw):
    """Execute an already-resolved plan through the exec cache — the fast
    path for callers that ran :func:`resolve_algo` themselves (Communicator
    methods resolve once with their own selector, then come here)."""
    key = (mesh, topo, name, algo, stacked, _kw_key(kw),
           (tuple(x.shape), str(x.dtype)), _codecs.fused_enabled())
    tm_on = _tm.enabled()  # one global read; the disabled path adds nothing
    t0 = _time.perf_counter() if tm_on else 0.0
    compiled = _EXEC_CACHE.get(key)
    if compiled is not None:
        _STATS.exec_hits += 1
        _EXEC_CACHE.move_to_end(key)
        cache = "hit"
    else:
        _STATS.exec_misses += 1
        cache = "miss"
        with (_tm.span(f"compile/{name}", cat="compile",
                       **_span_tags(topo, name, algo, kw))
              if tm_on else _tm.span("")):
            jitted = build(mesh, topo, name, algo, stacked=stacked,
                           jit=True, **kw)
            compiled = jitted.lower(x).compile()
        _EXEC_CACHE[key] = compiled
        _evict(_EXEC_CACHE, "exec")
    out = compiled(x)
    if tm_on:
        # dispatch wall-clock only (async: the device may still be running)
        dt = _time.perf_counter() - t0
        nbytes = _message_bytes(name, topo, x)
        _tm.emit(name, t0, dt, cat="collective", cache=cache,
                 **_span_tags(topo, name, algo, kw, nbytes=nbytes))
        _tm.observe_plan(topo, name, str(x.dtype), nbytes,
                         autotune.encode_plan(algo,
                                              int(kw.get("chunks", 1)),
                                              str(kw.get("codec", "none"))),
                         dt, synced=False)
    return out


def input_sharding(mesh, topo: Topology, collective: str) -> NamedSharding:
    """The canonical operand sharding for one collective's wiring — what
    persistent ops compile against (and reshard stray operands to)."""
    if collective not in _WIRING:
        raise ValueError(f"unknown collective {collective!r}; "
                         f"one of {collectives()}")
    del topo  # operands are global over the whole mesh (cf. _construct)
    return NamedSharding(mesh, _in_spec(_WIRING[collective].in_mode,
                                        tuple(mesh.axis_names)))


def _dist_backend():
    from repro.distributed import backend as _dist  # lazy: core stays
    return _dist                                    # importable standalone


def to_sharding(x, sharding):
    """Commit ``x`` to ``sharding`` as a (possibly cross-process) global.

    Single-process this is exactly ``device_put`` — bit-identical to the
    historical behavior, including the exec-cache interaction. Under a
    multi-controller runtime a host value becomes a global array with each
    process contributing its own shards, and an existing non-addressable
    global on the wrong sharding is resharded through a jitted identity
    (``device_put`` cannot move shards it does not own).
    """
    dist = _dist_backend()
    if not dist.is_multiprocess():
        return jax.device_put(x, sharding)
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        if x.sharding == sharding:
            return x
        return jax.jit(lambda v: v, out_shardings=sharding)(x)
    return dist.global_array(np.asarray(x), sharding)


def global_operand(mesh, collective, x):
    """Canonicalize one collective operand for ``mesh``.

    Single-process: plain ``jnp.asarray`` (uncommitted, so the exec cache
    keeps mixing committed/uncommitted operands exactly as before). Under a
    multi-controller runtime every operand is committed to the collective's
    canonical :func:`input_sharding` so compiled executables always see one
    layout — each process passes the same full logical value.
    """
    dist = _dist_backend()
    if not dist.is_multiprocess():
        return jnp.asarray(x)
    return to_sharding(x, input_sharding(mesh, None, collective))


def compile_persistent(mesh, topo: Topology, name: str, algo: str,
                       shape: Tuple[int, ...], dtype, *,
                       stacked: bool = True, donate: bool = False,
                       carry: bool = False,
                       **kw) -> Tuple[Callable, NamedSharding]:
    """AOT-compile one resolved plan for a fixed operand shape/dtype with
    the collective's canonical input sharding pinned (``PersistentOp``
    backend). Returns ``(compiled, in_sharding)``.

    ``carry=True`` compiles the carry-threaded program variant: the
    executable takes ``(x, carry)`` — both with the payload's shape, dtype
    and sharding — and returns ``(result, new_carry)``. This is how
    per-bucket error-feedback state rides a persistent compressed
    allreduce (``op.start(x, carry=err)`` -> ``handle.wait()`` ->
    ``(y, new_err)``); only algorithms with an ``err`` state operand
    support it (:func:`supports_carry`).

    Entries live in the same LRU exec cache as :func:`run`, keyed with the
    pinned sharding (a blocking call compiled against a host-local operand
    layout is a different executable) — re-initialising a persistent op
    with an identical spec is an exec-cache hit, never a recompile.
    """
    if algo == AUTO:
        raise ValueError("compile_persistent needs a resolved plan; call "
                         "resolve_algo first (Communicator.persistent "
                         "does this)")
    sharding = input_sharding(mesh, topo, name)
    key = (mesh, topo, name, algo, stacked, _kw_key(kw),
           (tuple(shape), str(jnp.dtype(dtype))),
           ("persistent", donate, carry), _codecs.fused_enabled())
    compiled = _EXEC_CACHE.get(key)
    if compiled is not None:
        _STATS.exec_hits += 1
        _EXEC_CACHE.move_to_end(key)
        if _tm.enabled():
            _tm.instant(f"persistent_cache_hit/{name}", cat="cache",
                        **_span_tags(topo, name, algo, kw))
        return compiled, sharding
    _STATS.exec_misses += 1
    with _tm.span(f"persistent_compile/{name}", cat="compile",
                  **(_span_tags(topo, name, algo, kw)
                     if _tm.enabled() else {})):
        jitted = build(mesh, topo, name, algo, stacked=stacked, jit=True,
                       donate=donate, carry=carry, **kw)
        proto = jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype),
                                     sharding=sharding)
        compiled = (jitted.lower(proto, proto).compile() if carry
                    else jitted.lower(proto).compile())
    _EXEC_CACHE[key] = compiled
    _evict(_EXEC_CACHE, "exec")
    return compiled, sharding


# ---------------------------------------------------------------------------
# calibration: measured sweeps -> the selector's tuning table
# ---------------------------------------------------------------------------


def example_input(collective: str, topo: Topology, nbytes: int,
                  dtype=jnp.float32, devices: Optional[int] = None):
    """A global operand for ``collective`` sized so the per-process message
    is ``nbytes`` (the cost model's size convention).

    ``devices`` is the total mesh device count ``D`` the operand's sharded
    dim0 spans (see :func:`build`'s conventions); it defaults to
    ``topo.world`` and must be passed for sub-communicator topologies,
    where the group size ``G = topo.world`` is smaller than the mesh."""
    G = topo.world
    D = int(devices) if devices is not None else G
    itemsize = jnp.dtype(dtype).itemsize
    elems = max(1, nbytes // itemsize)
    if collective == "allgather":
        return jnp.arange(D * elems, dtype=dtype)
    if collective == "scatter":
        return jnp.arange(G * elems, dtype=dtype)
    if collective == "broadcast":
        return jnp.arange(elems, dtype=dtype)
    if collective == "allreduce":
        return (jnp.arange(D * elems, dtype=dtype) % 13).reshape(D, elems)
    if collective == "reduce_scatter":
        s = max(1, elems // G)
        return (jnp.arange(D * G * s, dtype=dtype) % 11).reshape(D, G * s)
    if collective == "alltoall":
        s = max(1, elems // G)
        return jnp.arange(D * G * s, dtype=dtype).reshape(D, G, s)
    raise ValueError(collective)


@dataclasses.dataclass(frozen=True)
class CalibrationRow:
    collective: str
    algo: str
    nbytes: int
    dtype: str
    seconds: float
    chunks: int = 1
    codec: str = "none"
    #: sub-communicator group tag ("" = the root topology); split-lattice
    #: sweeps (Communicator.calibrate(include_splits=True)) fill this
    group: str = ""


def calibrate(mesh, topo: Topology,
              names: Optional[Iterable[str]] = None,
              sizes: Iterable[int] = (256, 4096, 65536),
              dtype=jnp.float32, iters: int = 10,
              selector: Optional[autotune.Selector] = None,
              codecs: Optional[Tuple[str, ...]] = None,
              path=None) -> List[CalibrationRow]:
    """Timed sweeps of every candidate plan x size, through the same
    compiled-callable path hot loops use, recorded into the selector's
    tuning table (and saved to ``path`` as JSON when given).

    Plans cover every feasible algorithm, chunk-count variants for the
    pipelined ones, and codec variants for the codec-capable ones
    (``codecs=()`` restricts to lossless plans). After calibration,
    ``algo="auto"`` on this (topology, collective, dtype, size bucket)
    resolves from measurement instead of the cost-model prior — codec
    entries still gated by the caller's ``error_budget`` at choose time.
    Calibrate with the same topology link metadata consumers use (e.g. both
    via ``Topology.from_mesh``) — the tuning-table key includes the links.
    """
    sel = selector or autotune.default_selector()
    rows: List[CalibrationRow] = []
    n_dev = int(np.asarray(mesh.devices).size)
    for name in (tuple(names) if names else collectives()):
        for nbytes in sizes:
            x = example_input(name, topo, int(nbytes), dtype,
                              devices=n_dev)
            for algo, chunks, codec in autotune.plans(
                    name, topo, int(nbytes), codecs=codecs,
                    dtype=str(jnp.dtype(dtype))):
                kw = {}
                if _mcoll.supports_chunks(name, algo):
                    kw["chunks"] = chunks
                if codec != _codecs.NONE:
                    kw["codec"] = codec
                plan = autotune.encode_plan(algo, chunks, codec)
                with _tm.span(f"calibrate/{name}/{plan}", cat="calibrate",
                              **(_span_tags(topo, name, algo, kw,
                                            nbytes=int(nbytes))
                                 if _tm.enabled() else {})):
                    jax.block_until_ready(
                        run(mesh, topo, name, algo, x, **kw))  # compile
                    samples = []
                    for _ in range(max(1, iters)):
                        t0 = _time.perf_counter()
                        jax.block_until_ready(
                            run(mesh, topo, name, algo, x, **kw))
                        samples.append(_time.perf_counter() - t0)
                sec = float(np.median(samples))
                if _tm.enabled():
                    # blocked loops are the highest-quality drift evidence
                    for s in samples:
                        _tm.observe_plan(topo, name, str(jnp.dtype(dtype)),
                                         int(nbytes), plan, s, synced=True)
                sel.table.record(topo, name, str(jnp.dtype(dtype)),
                                 int(nbytes), plan, sec)
                rows.append(CalibrationRow(name, algo, int(nbytes),
                                           str(jnp.dtype(dtype)), sec,
                                           chunks, codec,
                                           group=topo.group or ""))
    if path is not None:
        sel.table.save(path)
    return rows

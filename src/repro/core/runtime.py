"""Collective runtime: the single entry point for building and invoking
shard_map'd collectives.

This layer owns, for the whole codebase:

  1. **version portability** — all shard_map construction flows through
     ``repro.core.compat`` (the only module allowed to touch the raw JAX
     entry point), so a JAX API move is absorbed in one place;
  2. **wiring** — the per-collective ``body`` / ``in_specs`` / ``out_specs``
     conventions live in the declarative :data:`_WIRING` table instead of
     being re-derived at every call site;
  3. **caching** — mirroring how mpi4jax funnels every MPI primitive through
     one token-threaded dispatch layer, repeated invocations from
     training / serving / benchmark loops reuse both the built callable
     (keyed on mesh + collective + algo + kwargs) and the AOT-compiled
     executable (additionally keyed on input shape/dtype), so re-trace and
     re-jit overhead disappears from hot paths and measured numbers.

Public API:

  * :func:`collective` — run a collective through the compiled-callable
    cache (the supported entry point for hot loops).
  * :func:`build` — get the cached jitted callable for a collective key.
  * :func:`sharded` — version-portable shard_map for custom bodies (MoE
    expert-parallel dispatch, the manual train step, ad-hoc checks).
  * :func:`cache_stats` / :func:`clear_cache` — observe / reset the caches.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import mcoll as _mcoll
from repro.core.topology import Topology

# ---------------------------------------------------------------------------
# version-portable shard_map for custom bodies
# ---------------------------------------------------------------------------


def sharded(body: Callable, mesh, in_specs: Any, out_specs: Any,
            check: bool = False) -> Callable:
    """Wrap ``body`` with a version-portable shard_map over ``mesh``.

    This is the supported way to shard_map a custom body anywhere in the
    codebase; it keeps direct JAX-API references confined to ``compat``.
    """
    return compat.shard_map(body, mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=check)


# ---------------------------------------------------------------------------
# declarative wiring table: collective -> shard_map conventions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Wiring:
    """How one collective maps global arrays onto per-device bodies.

    in_mode:    "shard"     input dim0 sharded over the flat (node, local)
                            axis tuple,
                "replicate" input replicated,
                "row"       input dim0 sharded, each device's shard is one
                            leading row (the body consumes ``x[0]``).
    out_mode:   "stack"     per-device results stacked along a new dim0
                            (row d = device d's result),
                "shard"     output dim0 sharded,
                "replicate" output replicated.
    take_row0:  body consumes ``x[0]`` rather than ``x``.
    stackable:  honors ``stacked=False`` by switching out_mode to
                "replicate" (allgather's replicated-output variant).
    """

    in_mode: str
    out_mode: str
    take_row0: bool = False
    stackable: bool = False


_WIRING: Dict[str, Wiring] = {
    "allgather": Wiring("shard", "stack", stackable=True),
    "scatter": Wiring("replicate", "shard"),
    "broadcast": Wiring("replicate", "stack"),
    "allreduce": Wiring("row", "stack", take_row0=True),
    "reduce_scatter": Wiring("row", "shard", take_row0=True),
    "alltoall": Wiring("row", "stack", take_row0=True),
}


def _in_spec(mode: str, ax) -> P:
    return {"shard": P(ax), "replicate": P(None), "row": P(ax, None)}[mode]


def _out_spec(mode: str, ax) -> P:
    return {"stack": P(ax, None), "shard": P(ax), "replicate": P(None)}[mode]


def collectives() -> Tuple[str, ...]:
    return tuple(sorted(_WIRING))


def algorithms(collective: str):
    """Algorithm names registered for ``collective`` (see core.mcoll)."""
    return _mcoll.algorithms(collective)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    build_hits: int = 0
    build_misses: int = 0
    exec_hits: int = 0
    exec_misses: int = 0

    @property
    def exec_hit_rate(self) -> float:
        total = self.exec_hits + self.exec_misses
        return self.exec_hits / total if total else 0.0


_BUILD_CACHE: Dict[tuple, Callable] = {}
_EXEC_CACHE: Dict[tuple, Callable] = {}
_STATS = CacheStats()


def cache_stats() -> CacheStats:
    return _STATS


def clear_cache() -> None:
    _BUILD_CACHE.clear()
    _EXEC_CACHE.clear()
    # reset in place so handles returned by cache_stats() stay live
    _STATS.build_hits = _STATS.build_misses = 0
    _STATS.exec_hits = _STATS.exec_misses = 0


def _kw_key(kw: Dict[str, Any]) -> tuple:
    return tuple(sorted(kw.items()))


# ---------------------------------------------------------------------------
# construction + compiled-callable cache
# ---------------------------------------------------------------------------


def _construct(mesh, topo: Topology, collective: str, algo: str,
               stacked: bool, jit: bool, **kw) -> Callable:
    wiring = _WIRING[collective]
    fn = partial(_mcoll.algorithm(collective, algo), topo=topo, **kw)
    ax = topo.axes
    out_mode = wiring.out_mode
    if wiring.stackable and not stacked:
        out_mode = "replicate"
    take_row0, stack_out = wiring.take_row0, out_mode == "stack"

    def body(x):
        y = fn(x[0] if take_row0 else x)
        return y[None] if stack_out else y

    mapped = sharded(body, mesh, in_specs=(_in_spec(wiring.in_mode, ax),),
                     out_specs=_out_spec(out_mode, ax), check=False)
    return jax.jit(mapped) if jit else mapped


def build(mesh, topo: Topology, collective: str, algo: str, *,
          stacked: bool = True, jit: bool = True, **kw) -> Callable:
    """Build (or fetch from cache) the jitted shard_map'd callable for one
    collective key. Identical keys return the identical callable object, so
    jit's trace cache is shared across call sites.

    Key: (mesh axes/shape/devices, collective, algo, stacked, jit, kwargs).
    Input shape/dtype enter at :func:`collective` time via jit's own trace
    cache (and explicitly in the exec cache).
    """
    if collective not in _WIRING:
        raise ValueError(f"unknown collective {collective!r}; "
                         f"one of {collectives()}")
    # Mesh hashes/compares by axis names + device assignment, so it keys
    # the cache directly (no per-call O(n_devices) key construction)
    key = (mesh, topo, collective, algo, stacked, jit, _kw_key(kw))
    hit = _BUILD_CACHE.get(key)
    if hit is not None:
        _STATS.build_hits += 1
        return hit
    _STATS.build_misses += 1
    built = _construct(mesh, topo, collective, algo, stacked, jit, **kw)
    _BUILD_CACHE[key] = built
    return built


def collective(mesh, topo: Topology, name: str, algo: str, x, *,
               stacked: bool = True, **kw):
    """Run collective ``name`` with ``algo`` on ``x`` over ``mesh``.

    The supported entry point for hot loops: the AOT-compiled executable is
    cached on (mesh, collective, algo, input shape/dtype, kwargs), so every
    invocation after the first with an identical key skips trace, lowering
    and compilation entirely.
    """
    x = jnp.asarray(x)
    key = (mesh, topo, name, algo, stacked, _kw_key(kw),
           (tuple(x.shape), str(x.dtype)))
    compiled = _EXEC_CACHE.get(key)
    if compiled is not None:
        _STATS.exec_hits += 1
    else:
        _STATS.exec_misses += 1
        jitted = build(mesh, topo, name, algo, stacked=stacked, jit=True, **kw)
        compiled = jitted.lower(x).compile()
        _EXEC_CACHE[key] = compiled
    return compiled(x)

"""Two-level topology descriptor for multi-object collectives.

The paper's world is (nodes × processes-per-node). On TPU the same structure
is (inter-group axis × intra-group axis): e.g. ("pod", chips-per-pod) across
DCN, or ("node-group", chips) across a long ICI axis. `Topology` names the
two mesh axes the collective algorithms operate over; sizes are taken from
the enclosing `shard_map` mesh at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Topology:
    """A two-level (inter, intra) communication topology.

    Attributes:
      n_nodes: number of groups along the inter ("node") axis.
      n_local: number of devices per group along the intra ("local") axis.
      node_axis: mesh axis name for the inter-group dimension.
      local_axis: mesh axis name for the intra-group dimension.
    """

    n_nodes: int
    n_local: int
    node_axis: str = "node"
    local_axis: str = "local"

    def __post_init__(self):
        if self.n_nodes < 1 or self.n_local < 1:
            raise ValueError(f"invalid topology {self.n_nodes}x{self.n_local}")

    @property
    def world(self) -> int:
        return self.n_nodes * self.n_local

    @property
    def axes(self) -> Tuple[str, str]:
        return (self.node_axis, self.local_axis)

    def flat(self, node: int, local: int) -> int:
        """Flat device index under row-major (node, local) ordering.

        Matches `jax.lax.axis_index((node_axis, local_axis))` semantics.
        """
        return node * self.n_local + local

    @classmethod
    def from_mesh(cls, mesh, node_axis: str = "node", local_axis: str = "local"):
        return cls(
            n_nodes=mesh.shape[node_axis],
            n_local=mesh.shape[local_axis],
            node_axis=node_axis,
            local_axis=local_axis,
        )

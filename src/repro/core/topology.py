"""Two-level topology descriptor for multi-object collectives.

The paper's world is (nodes × processes-per-node). On TPU the same structure
is (inter-group axis × intra-group axis): e.g. ("pod", chips-per-pod) across
DCN, or ("node-group", chips) across a long ICI axis. `Topology` names the
two mesh axes the collective algorithms operate over; sizes are taken from
the enclosing `shard_map` mesh at trace time.

A topology additionally carries *link metadata* per level: ``node_link``
describes the inter-group fabric and ``local_link`` the intra-group one.
Each is either a :class:`repro.core.costmodel.NetParams` preset name (e.g.
``"tpu_v5e_dcn"``) or a ``NetParams`` instance override. The algorithm
selector (``repro.core.autotune``) composes the two into one cost-model
parameterisation via ``costmodel.net_for(topo)``, so selection no longer
assumes one hardcoded network. ``from_mesh`` auto-derives the links from
the mesh's devices when not given explicitly.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Set, Tuple

#: platforms already warned about in :func:`derive_link` fallback (warn once
#: per platform per process, so calibration logs record which link rows are
#: folklore without drowning in repeats)
_FALLBACK_WARNED: Set[str] = set()


def _axis_crossings(mesh, axis: str) -> Set[str]:
    """Boundary fields (``process_index`` / ``slice_index``) that vary along
    ``axis``, walked at the origin of all other mesh axes. Empty for
    degenerate size-1 axes (no traffic) and on any introspection failure."""
    crossed: Set[str] = set()
    try:
        idx = list(mesh.axis_names).index(axis)
        if mesh.devices.shape[idx] == 1:
            return crossed
        sel: list = [0] * mesh.devices.ndim
        sel[idx] = slice(None)
        lane = mesh.devices[tuple(sel)]
        for field in ("process_index", "slice_index"):
            vals = {getattr(d, field, None) for d in lane.flat}
            vals.discard(None)
            if len(vals) > 1:
                crossed.add(field)
    except (KeyError, ValueError, TypeError):
        pass
    return crossed


def _warn_fallback(platform: str, link: str) -> None:
    if platform in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(platform)
    warnings.warn(
        f"derive_link: no measured NetParams preset for platform "
        f"{platform!r}; falling back to {link!r} constants — calibration "
        f"rows keyed on this link class are folklore until a preset is "
        f"added to costmodel.NET_PRESETS", RuntimeWarning, stacklevel=3)


def derive_link(mesh, axis: str, level: str) -> str:
    """Link-class name for one mesh axis (overridable per Topology).

    Process boundaries classify first: an axis whose devices span multiple
    ``process_index`` values is an *inter* link regardless of platform —
    that is the node-boundary hierarchy the multi-leader algorithms split
    on. Then the platform names the preset:

      * cpu:  cross-process -> "host_ipc", in-process -> "host_cpu"
      * tpu:  cross-process/slice -> "tpu_v5e_dcn", else -> "tpu_v5e_ici"
      * anything else: classified the same way from process boundaries but
        mapped onto the host presets, with a once-per-platform warning so
        calibration tables record which rows rest on folklore constants.

    Degenerate size-1 axes carry no traffic and take the intra-class link.
    """
    del level  # boundary walk is what distinguishes levels, not the caller
    try:
        dev0 = mesh.devices.flat[0]
    except (AttributeError, IndexError):
        _warn_fallback("<no devices>", "host_cpu")
        return "host_cpu"
    platform = getattr(dev0, "platform", None) or "<unknown>"
    crossed = _axis_crossings(mesh, axis)
    if platform == "cpu":
        return "host_ipc" if "process_index" in crossed else "host_cpu"
    if platform == "tpu":
        return "tpu_v5e_dcn" if crossed else "tpu_v5e_ici"
    link = "host_ipc" if "process_index" in crossed else "host_cpu"
    _warn_fallback(platform, link)
    return link


@dataclasses.dataclass(frozen=True)
class Topology:
    """A two-level (inter, intra) communication topology.

    Attributes:
      n_nodes: number of groups along the inter ("node") axis.
      n_local: number of devices per group along the intra ("local") axis.
      node_axis: mesh axis name for the inter-group dimension.
      local_axis: mesh axis name for the intra-group dimension.
      node_link: link metadata for the inter level — a NetParams preset name
        or a NetParams instance (None = selector default).
      local_link: link metadata for the intra level, same conventions.
      group: group tag for sub-communicator topologies (empty for the root).
        Set by :meth:`subset` / ``Communicator.split``; it namespaces the
        tuning-table and plan-cache keys so an 8-way TP group and a 2-way DP
        group calibrate and cache independently, while siblings of identical
        shape (same tag) share entries.
    """

    n_nodes: int
    n_local: int
    node_axis: str = "node"
    local_axis: str = "local"
    node_link: Optional[object] = None
    local_link: Optional[object] = None
    group: str = ""

    def __post_init__(self):
        if self.n_nodes < 1 or self.n_local < 1:
            raise ValueError(f"invalid topology {self.n_nodes}x{self.n_local}")

    @property
    def world(self) -> int:
        return self.n_nodes * self.n_local

    @property
    def axes(self) -> Tuple[str, str]:
        return (self.node_axis, self.local_axis)

    @property
    def active_axes(self) -> Tuple[str, ...]:
        """Mesh axes this topology actually communicates over (size > 1).

        Degenerate size-1 levels carry no traffic; dropping them keeps
        sharding specs and collective axis tuples minimal. A fully
        degenerate 1x1 topology still names ``(local_axis,)`` so specs
        stay well-formed.
        """
        sizes = {self.node_axis: self.n_nodes, self.local_axis: self.n_local}
        # dict-keyed to dedupe: a single-axis topology names the same mesh
        # axis at both levels (node_axis == local_axis)
        active = tuple({a: None for a in self.axes if sizes[a] > 1})
        return active or (self.local_axis,)

    @property
    def link_names(self) -> Tuple[str, str]:
        """(inter, intra) link names — stable key material for tuning tables."""
        def name(link, default):
            if link is None:
                return default
            return getattr(link, "name", None) or str(link)
        return (name(self.node_link, "default"),
                name(self.local_link, "default"))

    def with_links(self, node_link=None, local_link=None) -> "Topology":
        """Copy with link metadata filled in (None leaves a field as is)."""
        return dataclasses.replace(
            self,
            node_link=node_link if node_link is not None else self.node_link,
            local_link=(local_link if local_link is not None
                        else self.local_link))

    def flat(self, node: int, local: int) -> int:
        """Flat device index under row-major (node, local) ordering.

        Matches `jax.lax.axis_index((node_axis, local_axis))` semantics.
        """
        return node * self.n_local + local

    @classmethod
    def subset(cls, mesh, axes, parent: Optional["Topology"] = None,
               group: Optional[str] = None) -> "Topology":
        """Derive a sub-communicator Topology from one or two mesh axes.

        One axis -> a flat ``1 x size`` intra-only topology over that axis
        (node level degenerate, so algorithms run their local stage only).
        Two axes -> a full two-level ``(axes[0], axes[1])`` topology.
        Link classes are inherited from ``parent`` when the axis matches one
        of the parent's levels, else auto-derived from the mesh devices.
        ``group`` overrides the group tag (defaults to the joined axis
        names), which namespaces tuning tables and plan caches per group
        shape.
        """
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(axes)
        if not 1 <= len(axes) <= 2:
            raise ValueError(f"subset takes 1 or 2 mesh axes, got {axes!r}")
        for a in axes:
            if a not in mesh.shape:
                raise ValueError(f"axis {a!r} not in mesh axes "
                                 f"{tuple(mesh.axis_names)}")

        def link_for(axis, level):
            if parent is not None:
                if axis == parent.node_axis and parent.node_link is not None:
                    return parent.node_link
                if axis == parent.local_axis and parent.local_link is not None:
                    return parent.local_link
            return derive_link(mesh, axis, level)

        tag = group if group is not None else "x".join(axes)
        if len(axes) == 1:
            (ax,) = axes
            return cls(1, mesh.shape[ax], node_axis=ax, local_axis=ax,
                       node_link=link_for(ax, "intra"),
                       local_link=link_for(ax, "intra"), group=tag)
        node_ax, local_ax = axes
        if node_ax == local_ax:
            raise ValueError(f"duplicate axis {node_ax!r} in subset axes")
        return cls(mesh.shape[node_ax], mesh.shape[local_ax],
                   node_axis=node_ax, local_axis=local_ax,
                   node_link=link_for(node_ax, "inter"),
                   local_link=link_for(local_ax, "intra"), group=tag)

    @classmethod
    def from_mesh(cls, mesh, node_axis: str = "node", local_axis: str = "local",
                  node_link: Optional[object] = None,
                  local_link: Optional[object] = None):
        """Build a Topology from a mesh, auto-deriving link metadata from the
        mesh's devices when not passed explicitly."""
        if node_link is None:
            node_link = derive_link(mesh, node_axis, level="inter")
        if local_link is None:
            local_link = derive_link(mesh, local_axis, level="intra")
        return cls(
            n_nodes=mesh.shape[node_axis],
            n_local=mesh.shape[local_axis],
            node_axis=node_axis,
            local_axis=local_axis,
            node_link=node_link,
            local_link=local_link,
        )

"""Alpha-beta-gamma cost models for multi-object collectives.

The paper evaluates end-to-end latency on a real cluster (128 x Xeon
Broadwell, 18 ppn, Intel OPA: 100 Gb/s, 97 M msg/s). No such cluster exists
here, so the benchmark harness reproduces the paper's figures through this
analytical model, instantiated with (a) the paper's cluster constants and
(b) TPU v5e pod constants for the TPU-native adaptation.

Model: a collective is a sequence of rounds. An inter-node round costs
    alpha_inter + (msgs_per_nic - 1)/msg_rate + bytes_per_nic * beta_inter
(the msg_rate term is how the paper's 97 M msg/s NIC injection rate enters —
multi-object designs deliberately spend it to buy rounds). An intra-node
round costs
    alpha_intra + bytes * beta_intra * copy_factor
where copy_factor models the library's intra-node mechanism (PiP = 1 single
copy & no syscall; POSIX SHMEM = 2 copies; CMA/XPMEM = 1 copy + syscall
latency folded into alpha_intra).

Every cost function also returns the round/volume breakdown so tests can
check the shard_map implementations emit exactly the predicted number of
collective-permute rounds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core import compress as _codecs
from repro.core.topology import Topology
from repro.core.mcoll import mo_rounds

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetParams:
    """Network/machine constants for the alpha-beta model."""
    name: str
    alpha_inter: float          # s per inter-node message
    beta_inter: float           # s per byte on one NIC / inter link
    alpha_intra: float          # s per intra-node transfer (incl. syscalls)
    beta_intra: float           # s per byte intra-node
    msg_rate: float             # NIC injection rate, messages/s
    copy_factor: float = 1.0    # intra-node copies per transfer
    sync_overhead: float = 0.0  # fixed per-collective sync cost
    flop_rate: float = 2.0e11   # codec elements/s per elementwise pass
    #                             (~HBM-bound: encode/decode are streaming)


# -- the paper's cluster (Sec. 3): Intel OPA, 100 Gb/s, 97 M msg/s ----------
# alpha_inter ~= 1.1 us is the standard MPI pt2pt small-message latency on
# OPA; intra-node constants encode each library's mechanism.

def paper_cluster_pip() -> NetParams:
    """PiP-MColl / PiP: shared address space — single copy, no syscalls."""
    return NetParams("pip", 1.1e-6, 1 / 12.5e9, 0.10e-6, 1 / 20e9, 97e6,
                     copy_factor=1.0)


def paper_cluster_posix_shmem() -> NetParams:
    """POSIX SHMEM (Intel MPI-style): double copy through a shared segment."""
    return NetParams("posix_shmem", 1.1e-6, 1 / 12.5e9, 0.25e-6, 1 / 20e9,
                     97e6, copy_factor=2.0)


def paper_cluster_cma() -> NetParams:
    """CMA/kernel-assisted (MVAPICH2-style): single copy but syscall+page
    fault overhead on every transfer."""
    return NetParams("cma", 1.1e-6, 1 / 12.5e9, 0.80e-6, 1 / 20e9, 97e6,
                     copy_factor=1.0)


def paper_cluster_openmpi() -> NetParams:
    """OpenMPI default (btl/vader two-sided): copy-in/copy-out."""
    return NetParams("openmpi", 1.2e-6, 1 / 12.5e9, 0.45e-6, 1 / 20e9, 97e6,
                     copy_factor=2.0)


def paper_cluster_pip_mpich() -> NetParams:
    """PiP-MPICH baseline: PiP memory but flat single-object algorithms and
    the message-size synchronization the paper calls out."""
    return NetParams("pip_mpich", 1.1e-6, 1 / 12.5e9, 0.10e-6, 1 / 20e9,
                     97e6, copy_factor=1.0, sync_overhead=1.5e-6)


# -- TPU v5e presets ---------------------------------------------------------
# intra = ICI (one pod axis), inter = DCN between pods.

def tpu_v5e_pod() -> NetParams:
    return NetParams("tpu_v5e_ici", alpha_inter=1.0e-6, beta_inter=1 / 4.5e10,
                     alpha_intra=0.8e-6, beta_intra=1 / 9.0e10, msg_rate=1e8)


def tpu_v5e_multipod() -> NetParams:
    return NetParams("tpu_v5e_dcn", alpha_inter=1.0e-5, beta_inter=1 / 2.5e10,
                     alpha_intra=1.0e-6, beta_intra=1 / 4.5e10, msg_rate=1e7)


def host_cpu() -> NetParams:
    """Forced host-platform CPU "devices" (dev boxes, CI): every transfer is
    an in-process memcpy; constants keep relative algorithm ordering sane for
    calibration runs, absolute times come from measurement."""
    return NetParams("host_cpu", alpha_inter=5.0e-7, beta_inter=1 / 2.0e10,
                     alpha_intra=2.0e-7, beta_intra=1 / 5.0e10, msg_rate=1e8)


def host_ipc() -> NetParams:
    """Cross-process boundary between local jax.distributed controllers
    (gloo over loopback/shared memory): far higher latency and lower
    bandwidth than in-process memcpy, which is exactly the intra/inter
    asymmetry the multi-leader algorithms exploit."""
    return NetParams("host_ipc", alpha_inter=6.0e-6, beta_inter=1 / 8.0e9,
                     alpha_intra=2.0e-7, beta_intra=1 / 5.0e10, msg_rate=2e7)


# name -> factory; the string side of Topology.node_link / local_link.
NET_PRESETS = {
    "pip": paper_cluster_pip,
    "posix_shmem": paper_cluster_posix_shmem,
    "cma": paper_cluster_cma,
    "openmpi": paper_cluster_openmpi,
    "pip_mpich": paper_cluster_pip_mpich,
    "tpu_v5e_ici": tpu_v5e_pod,
    "tpu_v5e_dcn": tpu_v5e_multipod,
    "host_cpu": host_cpu,
    "host_ipc": host_ipc,
}

_DEFAULT_PRESET = "tpu_v5e_dcn"


def resolve_net(spec) -> NetParams:
    """A NetParams from a preset name, a NetParams instance, or None
    (selector default)."""
    if spec is None:
        spec = _DEFAULT_PRESET
    if isinstance(spec, NetParams):
        return spec
    try:
        return NET_PRESETS[spec]()
    except KeyError:
        raise ValueError(f"unknown net preset {spec!r}; "
                         f"one of {sorted(NET_PRESETS)}") from None


def net_for(topo) -> NetParams:
    """Compose a Topology's per-axis link metadata into one NetParams.

    The inter-level constants (alpha_inter, beta_inter, msg_rate) come from
    ``topo.node_link``, the intra-level ones (alpha_intra, beta_intra,
    copy_factor, sync_overhead) from ``topo.local_link``; a missing link
    falls back to the other level's preset, then to the default preset.
    """
    inter = resolve_net(topo.node_link if topo.node_link is not None
                        else topo.local_link)
    intra = resolve_net(topo.local_link if topo.local_link is not None
                        else topo.node_link)
    if inter == intra:
        return inter
    return NetParams(
        name=f"{inter.name}+{intra.name}",
        alpha_inter=inter.alpha_inter, beta_inter=inter.beta_inter,
        alpha_intra=intra.alpha_intra, beta_intra=intra.beta_intra,
        msg_rate=inter.msg_rate, copy_factor=intra.copy_factor,
        sync_overhead=max(inter.sync_overhead, intra.sync_overhead),
        flop_rate=intra.flop_rate)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CostBreakdown:
    algo: str
    inter_rounds: int
    inter_bytes_per_nic: float
    inter_msgs_per_nic: int
    intra_rounds: int
    intra_bytes: float
    time: float

    def us(self) -> float:
        return self.time * 1e6


def _round_time(net: NetParams, msgs: int, nic_bytes: float) -> float:
    if msgs == 0:
        return 0.0
    return net.alpha_inter + (msgs - 1) / net.msg_rate + nic_bytes * net.beta_inter


def _intra_time(net: NetParams, rounds: int, total_bytes: float) -> float:
    return rounds * net.alpha_intra + total_bytes * net.beta_intra * net.copy_factor


def _log2_rounds(x: int) -> int:
    return max(0, math.ceil(math.log2(x))) if x > 1 else 0


# ---------------------------------------------------------------------------
# chunked pipelining: (C + P/c·beta) · (rounds + c - 1)
# ---------------------------------------------------------------------------
#
# A chunked collective runs `rounds` uniform stages per segment with c
# independent segments in flight: total latency is the classic pipeline
# fill-drain form (C + B/c·beta)·(rounds + c − 1), where C is the per-stage
# latency (alpha + injection), B the per-stage NIC bytes at c=1. Chunking
# trades (c−1) extra stage latencies for a c-fold smaller serialized wire
# term — a large-message win, a small-message loss, with an analytic
# optimum c* = sqrt(B·beta·(rounds−1)/C).

#: default upper bound on planned chunk counts (keeps unrolled per-segment
#: chains bounded in compile time and exec-cache keys finite)
MAX_CHUNKS = 64


def pipeline_time(stage_alpha: float, stage_bytes: float, beta: float,
                  rounds: int, chunks: int) -> float:
    """Latency of ``rounds`` uniform pipelined stages over ``chunks``
    segments: ``(C + B/c·beta) · (rounds + c − 1)``."""
    c = max(1, int(chunks))
    return (stage_alpha + (stage_bytes / c) * beta) * (rounds + c - 1)


def optimal_pipeline_chunks(stage_alpha: float, stage_bytes: float,
                            beta: float, rounds: int,
                            cap: int = MAX_CHUNKS) -> int:
    """Analytic minimizer of :func:`pipeline_time` over c, clamped to
    [1, cap] and snapped to the better integer neighbor:
    ``c* = sqrt(B·beta·(rounds−1)/C)``."""
    if rounds <= 1 or stage_alpha <= 0 or stage_bytes <= 0 or beta <= 0:
        return 1
    c = math.sqrt(stage_bytes * beta * (rounds - 1) / stage_alpha)
    lo = int(max(1, min(cap, math.floor(c))))
    hi = int(max(1, min(cap, lo + 1)))
    return min((lo, hi), key=lambda k: pipeline_time(
        stage_alpha, stage_bytes, beta, rounds, k))


@dataclasses.dataclass(frozen=True)
class PipelineTerms:
    """Uniform-stage decomposition of one pipelined (collective, algo):
    latency = fixed + pipeline_time(stage_alpha, stage_bytes, beta,
    rounds, chunks)."""
    stage_alpha: float   # per-stage latency C (alpha + injection serialization)
    stage_bytes: float   # per-stage NIC bytes B at chunks=1
    beta: float          # s/byte on the stage's link
    rounds: int          # stages per segment
    fixed: float         # unpipelined cost (intra staging passes, sync)


def pipeline_terms(collective: str, algo: str, topo: Topology, m: int,
                   net: NetParams):
    """The stage decomposition for a pipelined (collective, algo) pair, or
    ``None`` when the pair has no pipelined form (or the topology leaves it
    no rounds to overlap). ``m`` follows each cost function's size
    convention."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    inter = N > 1
    alpha = net.alpha_inter if inter else net.alpha_intra
    beta = net.beta_inter if inter else net.beta_intra * net.copy_factor
    if collective == "allgather" and algo == "ring_pipeline":
        if M <= 1:
            return None
        # flat ring: M-1 stages, each boundary NIC carries one block of m
        return PipelineTerms(alpha, float(m), beta, M - 1,
                             net.sync_overhead)
    if collective == "allreduce" and algo == "pip_pipeline":
        if inter:
            # intra RS + AG (unpipelined staging) ...
            fixed = net.sync_overhead + _intra_time(
                net, 2 * _log2_rounds(P), 2 * (P - 1) / max(P, 1) * m)
            # ... then per-lane ring RS+AG over nodes: 2(N-1) stages, all P
            # lanes concurrently inject (m/P)/N each -> m/N per NIC stage
            stage_a = net.alpha_inter + (P - 1) / net.msg_rate
            return PipelineTerms(stage_a, m / N, net.beta_inter,
                                 2 * (N - 1), fixed)
        if P <= 1:
            return None
        # flat single level: ring RS+AG over the local axis
        return PipelineTerms(alpha, m / P, beta, 2 * (P - 1),
                             net.sync_overhead)
    if collective == "alltoall" and algo == "pip_pipeline":
        if inter:
            fixed = net.sync_overhead + _intra_time(
                net, 1, m * (P - 1) / max(P, 1))
            stage_a = net.alpha_inter + (P - 1) / net.msg_rate
            return PipelineTerms(stage_a, P * m / N, net.beta_inter,
                                 N - 1, fixed)
        if P <= 1:
            return None
        return PipelineTerms(alpha, m / P, beta, P - 1, net.sync_overhead)
    if collective == "scatter" and algo == "pip_mcoll":
        if not inter:
            return None  # pure intra slice: nothing to pipeline
        B = P + 1
        n_rounds, cap = 1, B
        while cap < N:
            cap *= B
            n_rounds += 1
        # total root-NIC bytes from the unchunked tree, spread uniformly
        total = 0.0
        for S in (B ** i for i in range(n_rounds - 1, -1, -1)):
            nlanes = min(B - 1, max(1, math.ceil(N / S) - 1))
            total += sum(min(S, max(0, N - (j + 1) * S)) * P * m
                         for j in range(nlanes))
        stage_a = net.alpha_inter + (B - 2) / net.msg_rate
        fixed = net.sync_overhead + _intra_time(net, 1, m)
        return PipelineTerms(stage_a, total / n_rounds, net.beta_inter,
                             n_rounds, fixed)
    if collective == "broadcast" and algo == "pip_mcoll":
        if not inter:
            return None
        B = P + 1
        n_rounds, cap = 1, B
        while cap < N:
            cap *= B
            n_rounds += 1
        lanes = min(P, max(1, N - 1))
        stage_a = net.alpha_inter + (lanes - 1) / net.msg_rate
        fixed = net.sync_overhead + _intra_time(net, 1, m)
        return PipelineTerms(stage_a, float(lanes * m), net.beta_inter,
                             n_rounds, fixed)
    return None


def optimal_chunks(collective: str, algo: str, topo: Topology, m: int,
                   net: NetParams, cap: int = MAX_CHUNKS) -> int:
    """Analytic optimal chunk count for one pipelined pair on one message
    size (1 when the pair is not pipelined or pipelining cannot help)."""
    terms = pipeline_terms(collective, algo, topo, m, net)
    if terms is None:
        return 1
    return optimal_pipeline_chunks(terms.stage_alpha, terms.stage_bytes,
                                   terms.beta, terms.rounds, cap)


def pipeline_crossover_bytes(collective: str, algo: str, topo: Topology,
                             net: NetParams, sizes=None):
    """Smallest swept message size at which the optimally-chunked variant
    strictly beats ``chunks=1`` for one pipelined pair — the pipelining
    crossover. None when chunking never wins on the sweep (latency-bound
    topology or no rounds to overlap)."""
    fn = COST_FNS[collective]
    for s in (tuple(sizes) if sizes else tuple(2 ** i for i in range(6, 27))):
        c = optimal_chunks(collective, algo, topo, s, net)
        if c > 1 and (fn(algo, topo, s, net, chunks=c).time
                      < fn(algo, topo, s, net, chunks=1).time):
            return int(s)
    return None


def _pipelined_breakdown(collective: str, algo: str, topo: Topology, m: int,
                         net: NetParams, chunks):
    """CostBreakdown for a pipelined pair via the uniform-stage model, or
    None when the topology leaves the pair nothing to pipeline."""
    terms = pipeline_terms(collective, algo, topo, m, net)
    if terms is None:
        return None
    c = max(1, int(chunks or 1))
    t = terms.fixed + pipeline_time(terms.stage_alpha, terms.stage_bytes,
                                    terms.beta, terms.rounds, c)
    ib = terms.stage_bytes * terms.rounds
    if topo.n_nodes > 1:
        return CostBreakdown(algo, terms.rounds, ib, terms.rounds, 0, 0.0, t)
    return CostBreakdown(algo, 0, 0.0, 0, terms.rounds, ib, t)


# ----------------------------- ALLGATHER -----------------------------------


def allgather_cost(algo: str, topo: Topology, m: int, net: NetParams,
                   radix: int | None = None,
                   chunks: int | None = None) -> CostBreakdown:
    """m = bytes contributed per process. Result = N*P*m bytes everywhere."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    t = net.sync_overhead
    if algo == "ring_pipeline":
        bd = _pipelined_breakdown("allgather", algo, topo, m, net, chunks)
        return bd or CostBreakdown(algo, 0, 0.0, 0, 0, 0.0, t)
    if algo == "pip_mcoll":
        B = radix or (P + 1)
        steps = mo_rounds(N, B)
        # intra gather (tree over P):
        ir = _log2_rounds(P)
        ib = (P - 1) * m
        t += _intra_time(net, ir, ib)
        inter_bytes = 0.0
        msgs = 0
        s_cum = 1
        for S in steps:
            K = min((B - 1) * S, N - s_cum)  # useful fresh blocks
            nlanes = min(B - 1, -(-K // S))  # only useful lanes send
            lane_bytes = min(S, K) * P * m   # single-lane remainder is exact
            s_cum += K
            nic_bytes = nlanes * lane_bytes
            inter_bytes += nic_bytes
            msgs += nlanes
            t += _round_time(net, nlanes, nic_bytes)
            # PiP shared-buffer write of the received fragments (per lane,
            # parallel): one store pass
            t += _intra_time(net, 1, lane_bytes)
            ir += 1
            ib += lane_bytes
        # final shift: single memcpy pass over the result
        t += _intra_time(net, 1, N * P * m)
        ir += 1
        ib += N * P * m
        return CostBreakdown(algo, len(steps), inter_bytes, msgs, ir, ib, t)
    if algo in ("recursive_doubling", "bruck"):
        rounds = _log2_rounds(M)
        inter_bytes = 0.0
        intra_bytes = 0.0
        inter_rounds = 0
        intra_rounds = 0
        msgs = 0
        S = 1
        for i in range(rounds):
            vol = min(S, M - S) * m          # per-process send volume
            if S < P:                         # mostly intra-node partners
                intra_rounds += 1
                intra_bytes += vol
                t += _intra_time(net, 1, vol)
            else:
                inter_rounds += 1
                nic_bytes = P * vol           # all P procs cross the NIC
                inter_bytes += nic_bytes
                msgs += P
                t += _round_time(net, P, nic_bytes)
            S *= 2
        return CostBreakdown(algo, inter_rounds, inter_bytes, msgs,
                             intra_rounds, intra_bytes, t)
    if algo == "ring":
        # M-1 rounds; each round the NIC carries one boundary message of m.
        rounds = M - 1
        for _ in range(rounds):
            t += max(_round_time(net, 1, m), _intra_time(net, 1, m))
        return CostBreakdown(algo, rounds, rounds * m, rounds, 0, (M - 1) * m, t)
    if algo == "single_leader":
        ir = _log2_rounds(P)
        ib = (P - 1) * m
        t += _intra_time(net, ir, ib)
        inter_bytes = 0.0
        msgs = 0
        S = 1
        steps = 0
        while S < N:
            vol = min(S, N - S) * P * m      # leader ships S node-blocks
            inter_bytes += vol
            msgs += 1
            t += _round_time(net, 1, vol)
            S += min(S, N - S)
            steps += 1
        # leader broadcasts the N*P*m result intra-node (tree)
        br = _log2_rounds(P)
        t += _intra_time(net, br, N * P * m)
        return CostBreakdown(algo, steps, inter_bytes, msgs, ir + br,
                             ib + N * P * m, t)
    if algo == "xla":
        # vendor collective: model as bidirectional ring (bandwidth optimal)
        rounds = M - 1
        for _ in range(rounds):
            t += max(net.alpha_inter / 2 + m * net.beta_inter / 2,
                     _intra_time(net, 1, m))
        return CostBreakdown(algo, rounds, rounds * m / 2, rounds, 0,
                             (M - 1) * m, t)
    raise ValueError(algo)


# ----------------------------- SCATTER --------------------------------------


def scatter_cost(algo: str, topo: Topology, m: int, net: NetParams,
                 radix: int | None = None,
                 chunks: int | None = None) -> CostBreakdown:
    """m = bytes delivered per process (root holds N*P*m)."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    t = net.sync_overhead
    if algo == "pip_mcoll" and chunks and int(chunks) > 1:
        bd = _pipelined_breakdown("scatter", algo, topo, m, net, chunks)
        if bd is not None:
            return bd
    if algo == "pip_mcoll":
        B = radix or (P + 1)
        n_rounds = max(1, math.ceil(round(math.log(N, B), 9))) if N > 1 else 0
        steps = [B ** i for i in range(n_rounds - 1, -1, -1)]
        inter_bytes = 0.0
        msgs = 0
        for S in steps:
            # the root's NIC is the bottleneck: B-1 lanes x S node-blocks
            nlanes = min(B - 1, max(1, math.ceil(N / S) - 1))
            nic_bytes = sum(min(S, max(0, N - (j + 1) * S)) * P * m
                            for j in range(nlanes))
            msgs += nlanes
            inter_bytes += nic_bytes
            t += _round_time(net, nlanes, nic_bytes)
        # intra: each lane slices its block from the node block (PiP: one copy)
        t += _intra_time(net, 1, m)
        return CostBreakdown(algo, len(steps), inter_bytes, msgs, 1, m, t)
    if algo == "binomial":
        rounds = _log2_rounds(M)
        inter_bytes = 0.0
        intra_bytes = 0.0
        ir = 0
        ii = 0
        msgs = 0
        S = 2 ** max(0, rounds - 1)
        while S >= 1:
            vol = min(S, M - S) * m
            if S < P:
                ii += 1
                intra_bytes += vol
                t += _intra_time(net, 1, vol)
            else:
                ir += 1
                inter_bytes += vol
                msgs += 1
                t += _round_time(net, 1, vol)
            S //= 2
        return CostBreakdown(algo, ir, inter_bytes, msgs, ii, intra_bytes, t)
    if algo == "linear":
        # root sends M-1 direct messages (serialized at the root NIC)
        inter = (M - P) * m
        t += (M - 1) / net.msg_rate + _round_time(net, 1, inter)
        t += _intra_time(net, 1, (P - 1) * m)
        return CostBreakdown(algo, 1, inter, M - P, 1, (P - 1) * m, t)
    raise ValueError(algo)


# ----------------------------- ALLREDUCE ------------------------------------


def allreduce_cost(algo: str, topo: Topology, m: int, net: NetParams,
                   chunks: int | None = None) -> CostBreakdown:
    """m = bytes per process (vector size)."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    t = net.sync_overhead
    if algo == "pip_pipeline":
        bd = _pipelined_breakdown("allreduce", algo, topo, m, net, chunks)
        return bd or CostBreakdown(algo, 0, 0.0, 0, 0, 0.0, t)
    if algo == "pip_mcoll":
        # intra reduce-scatter + per-lane inter allreduce (RD) + intra gather
        ir = _log2_rounds(P) * 2
        ib = 2 * (P - 1) / P * m
        t += _intra_time(net, ir, ib)
        rounds = _log2_rounds(N)
        slice_bytes = m / P
        inter_bytes = 0.0
        for _ in range(rounds):
            nic = P * slice_bytes            # all P lanes exchange slices
            inter_bytes += nic
            t += _round_time(net, P, nic)
        return CostBreakdown(algo, rounds, inter_bytes, rounds * P, ir, ib, t)
    if algo == "recursive_doubling":
        rounds = _log2_rounds(M)
        inter_bytes = 0.0
        ir = ii = 0
        intra_bytes = 0.0
        S = 1
        for i in range(rounds):
            if S < P:
                ii += 1
                intra_bytes += m
                t += _intra_time(net, 1, m)
            else:
                ir += 1
                inter_bytes += P * m
                t += _round_time(net, P, P * m)
            S *= 2
        return CostBreakdown(algo, ir, inter_bytes, ir * P, ii, intra_bytes, t)
    if algo == "xla":
        # ring reduce-scatter + ring allgather (bandwidth optimal)
        rounds = 2 * (M - 1)
        for _ in range(rounds):
            t += net.alpha_inter / 2 + (m / M) * net.beta_inter
        return CostBreakdown(algo, rounds, 2 * (M - 1) * m / M, rounds, 0, 0, t)
    raise ValueError(algo)


# ----------------------------- BROADCAST ------------------------------------


def broadcast_cost(algo: str, topo: Topology, m: int, net: NetParams,
                   radix: int | None = None,
                   chunks: int | None = None) -> CostBreakdown:
    """m = bytes delivered to every process (root holds m)."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    t = net.sync_overhead
    if algo == "pip_mcoll" and chunks and int(chunks) > 1:
        bd = _pipelined_breakdown("broadcast", algo, topo, m, net, chunks)
        if bd is not None:
            return bd
    if algo == "pip_mcoll":
        B = radix or (P + 1)
        n_rounds, cap = (1, B) if N > 1 else (0, 1)
        while cap < N:
            cap *= B
            n_rounds += 1
        inter_bytes = 0.0
        msgs = 0
        for _ in range(n_rounds):
            # an active node's P lanes feed up to P child nodes concurrently:
            # its NIC carries up to P messages of m in the round
            lanes = min(P, max(1, N - 1))
            nic = lanes * m
            inter_bytes += nic
            msgs += lanes
            t += _round_time(net, lanes, nic)
        # intra share of the node copy (PiP: one pass over shared memory)
        t += _intra_time(net, 1, m)
        return CostBreakdown(algo, n_rounds, inter_bytes, msgs, 1, m, t)
    if algo == "binomial":
        rounds = _log2_rounds(M)
        inter_bytes = intra_bytes = 0.0
        ir = ii = msgs = 0
        S = 2 ** max(0, rounds - 1)
        while S >= 1 and M > 1:
            if S < P:
                ii += 1
                intra_bytes += m
                t += _intra_time(net, 1, m)
            else:
                ir += 1
                inter_bytes += m
                msgs += 1
                t += _round_time(net, 1, m)
            S //= 2
        return CostBreakdown(algo, ir, inter_bytes, msgs, ii, intra_bytes, t)
    if algo == "xla":
        # the implemented vendor broadcast is a masked psum (mcoll), i.e. a
        # full allreduce of the payload: price it as the vendor ring
        # allreduce so the prior matches what actually runs
        rounds = 2 * max(0, M - 1)
        for _ in range(rounds):
            t += net.alpha_inter / 2 + (m / M) * net.beta_inter
        return CostBreakdown(algo, rounds, 2 * (M - 1) * m / max(M, 1),
                             rounds, 0, 0.0, t)
    raise ValueError(algo)


# ------------------------- REDUCE_SCATTER -----------------------------------


def reduce_scatter_cost(algo: str, topo: Topology, m: int, net: NetParams
                        ) -> CostBreakdown:
    """m = bytes input per process; each process ends with m/M reduced."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    t = net.sync_overhead
    if algo == "pip_mcoll":
        # two-level: ring reduce-scatter over nodes first (all P lanes active
        # on disjoint slices -> big contiguous inter chunks), then over lanes
        # (pure intra)
        inter_rounds = max(0, N - 1)
        inter_bytes = 0.0
        msgs = 0
        for _ in range(inter_rounds):
            nic = P * (m / max(N, 1))
            inter_bytes += nic
            msgs += P
            t += _round_time(net, P, nic)
        intra_rounds = max(0, P - 1)
        intra_bytes = intra_rounds * (m / max(N * P, 1))
        t += _intra_time(net, intra_rounds, intra_bytes)
        return CostBreakdown(algo, inter_rounds, inter_bytes, msgs,
                             intra_rounds, intra_bytes, t)
    if algo == "xla":
        # flat ring over M ranks: M-1 rounds of m/M (bandwidth optimal)
        rounds = max(0, M - 1)
        for _ in range(rounds):
            t += net.alpha_inter / 2 + (m / M) * net.beta_inter
        return CostBreakdown(algo, rounds, rounds * m / max(M, 1), rounds,
                             0, 0.0, t)
    raise ValueError(algo)


# ----------------------------- ALLTOALL -------------------------------------


def alltoall_cost(algo: str, topo: Topology, m: int, net: NetParams,
                  chunks: int | None = None) -> CostBreakdown:
    """m = bytes sent per process in total (m/M per peer)."""
    N, P = topo.n_nodes, topo.n_local
    M = topo.world
    t = net.sync_overhead
    if algo == "pip_pipeline":
        bd = _pipelined_breakdown("alltoall", algo, topo, m, net, chunks)
        return bd or CostBreakdown(algo, 0, 0.0, 0, 0, 0.0, t)
    if algo == "pip_mcoll":
        # phase 1 (intra): regroup by destination lane — one shared-memory
        # pass over the (P-1)/P fraction leaving this lane
        t += _intra_time(net, 1, m * (P - 1) / max(P, 1))
        # phase 2 (inter, multi-lane): per-lane all-to-all over nodes; each
        # of the N-1 rounds ships m/N per lane, P lanes per NIC concurrently
        inter_rounds = max(0, N - 1)
        inter_bytes = 0.0
        msgs = 0
        for _ in range(inter_rounds):
            nic = P * (m / max(N, 1))
            inter_bytes += nic
            msgs += P
            t += _round_time(net, P, nic)
        return CostBreakdown(algo, inter_rounds, inter_bytes, msgs, 1,
                             m * (P - 1) / max(P, 1), t)
    if algo == "xla":
        # flat pairwise exchange: M-1 rounds of m/M each
        rounds = max(0, M - 1)
        for _ in range(rounds):
            t += net.alpha_inter / 2 + (m / M) * net.beta_inter
        return CostBreakdown(algo, rounds, rounds * m / max(M, 1), rounds,
                             0, 0.0, t)
    raise ValueError(algo)


COST_FNS = {
    "allgather": allgather_cost,
    "scatter": scatter_cost,
    "broadcast": broadcast_cost,
    "allreduce": allreduce_cost,
    "reduce_scatter": reduce_scatter_cost,
    "alltoall": alltoall_cost,
}


# ---------------------------------------------------------------------------
# compressed plans: (C + B/ratio·beta) · rounds + codec_flops
# ---------------------------------------------------------------------------
#
# A codec shrinks every wire-axis byte term by its wire ratio (the alpha and
# injection terms are unchanged — compression buys bandwidth, not rounds)
# and adds the encode/decode streaming passes, priced against the machine's
# elementwise throughput (NetParams.flop_rate). Crossovers therefore shift
# per codec: small messages stay lossless (the flop term dominates), large
# wire-bound messages go compressed.


def codec_seconds(codec: str, nbytes: float, net: NetParams) -> float:
    """Modeled encode+decode time for ``nbytes`` of fp32 payload.

    Prices :func:`compress.effective_flops_per_elem` — codecs with fused
    Pallas lowerings (encode+error-feedback and decode+reduce in one memory
    pass each) cost fewer streaming passes while fusion is enabled, so the
    autotuned compression crossover moves to smaller messages."""
    return (_codecs.effective_flops_per_elem(codec)
            * (float(nbytes) / 4.0) / net.flop_rate)


def codec_net(net: NetParams, topo: Topology, codec: str) -> NetParams:
    """``net`` with the wire-axis beta divided by the codec's wire ratio
    (the wire axis is the node level when present, else the local level —
    matching ``core.mcoll``'s compressed execution)."""
    if not codec or codec == _codecs.NONE:
        return net
    ratio = max(_codecs.meta(codec).wire_ratio, 1e-9)
    if topo.n_nodes > 1:
        return dataclasses.replace(net, beta_inter=net.beta_inter / ratio)
    return dataclasses.replace(net, beta_intra=net.beta_intra / ratio)


def plan_cost(collective: str, algo: str, topo: Topology, m: int,
              net: NetParams, chunks: int = 1,
              codec: str = "none") -> CostBreakdown:
    """Cost of one full ``(algo, chunks, codec)`` plan — the selection
    subsystem's single pricing entry point. ``codec="none"`` falls through
    to the plain cost function; a lossy codec scales the wire beta by its
    ratio and adds the encode/decode term."""
    fn = COST_FNS[collective]
    kw = {"chunks": int(chunks)} if chunks and int(chunks) > 1 else {}
    if not codec or codec == _codecs.NONE:
        return fn(algo, topo, m, net, **kw)
    ratio = max(_codecs.meta(codec).wire_ratio, 1e-9)
    bd = fn(algo, topo, m, codec_net(net, topo, codec), **kw)
    extra = codec_seconds(codec, m, net)
    return CostBreakdown(bd.algo, bd.inter_rounds,
                         bd.inter_bytes_per_nic / ratio,
                         bd.inter_msgs_per_nic, bd.intra_rounds,
                         bd.intra_bytes, bd.time + extra)


def plan_seconds(collective: str, algo: str, topo: Topology, m: int,
                 chunks: int = 1, codec: str = "none",
                 net=None) -> float:
    """Modeled seconds for one plan with the net defaulted from the
    topology's link metadata — the reference the telemetry drift detector
    prices observed plans against (``autotune.predicted_seconds`` decodes
    plan keys into this)."""
    net_p = net_for(topo) if net is None else resolve_net(net)
    return plan_cost(collective, algo, topo, m, net_p, chunks=chunks,
                     codec=codec).time


def compressed_crossover_bytes(collective: str, algo: str, topo: Topology,
                               net: NetParams, codec: str, sizes=None):
    """Smallest swept message size where the codec plan (at its optimal
    chunk count) strictly beats the lossless plan of the same algorithm —
    the compression crossover. None when the codec never wins the sweep
    (latency-bound topology, or flop cost exceeds the wire savings)."""
    cnet = codec_net(net, topo, codec)
    for s in (tuple(sizes) if sizes else tuple(2 ** i for i in range(6, 27))):
        c_lossless = optimal_chunks(collective, algo, topo, s, net)
        c_codec = optimal_chunks(collective, algo, topo, s, cnet)
        if (plan_cost(collective, algo, topo, s, net, c_codec, codec).time
                < plan_cost(collective, algo, topo, s, net, c_lossless).time):
            return int(s)
    return None


def sweep(collective: str, topo: Topology, sizes: List[int], net_by_algo:
          Dict[str, NetParams]) -> Dict[str, List[float]]:
    """Latency (us) per algorithm across message sizes; net params may differ
    per algorithm (modeling different MPI libraries)."""
    out: Dict[str, List[float]] = {}
    fn = COST_FNS[collective]
    for algo, net in net_by_algo.items():
        name = algo.split(":")[-1]
        out[algo] = [fn(name, topo, s, net).us() for s in sizes]
    return out

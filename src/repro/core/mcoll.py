"""PiP-MColl: multi-object hierarchical collectives for JAX/TPU.

Faithful TPU-native adaptation of *Accelerating MPI Collectives with
Process-in-Process-based Multi-object Techniques* (HPDC'23).

The paper's design for a (nodes x procs-per-node) cluster:

  1. intra-node phase into shared memory (PiP: zero-copy),
  2. inter-node phase where ALL P local processes act as communication
     objects simultaneously — a radix-(P+1) Bruck schedule over nodes where
     local rank ``l`` covers node-offset ``(l+1)*S`` each round,
  3. a final shift restores rank order.

TPU mapping: "node" and "local" are two mesh axes (e.g. pod x chips, where
the pod axis crosses DCN). MPI sends become static ``lax.ppermute`` calls
over the *tuple* axis ``(node, local)`` — the lane-dependent destination
becomes a single static permutation of all N*P devices, i.e. ONE
collective-permute per algorithm round. PiP shared-memory staging becomes
cheap intra-group collectives (``all_gather``/``psum`` over the local axis)
plus fused Pallas pack/shift kernels for the local data-reorder steps.

All algorithm functions in this module run INSIDE a shard_map over a mesh
that contains ``topo.node_axis`` and ``topo.local_axis``. Construction of
the shard_map'd callables lives in ``repro.core.runtime`` — use the
Communicator API (``repro.core.comm``: ``comm.allreduce(x, ...)``, cached
and version-portable) as the supported entry point, or ``runtime.build``
directly.

Algorithms (selectable, ``algo=`` everywhere):
  allgather : pip_mcoll | bruck | recursive_doubling | ring | ring_pipeline
              | single_leader | xla
  scatter   : pip_mcoll | binomial | xla(linear)
  broadcast : pip_mcoll | binomial | xla(psum-mask)
  allreduce : pip_mcoll (two-level multi-lane) | pip_pipeline (chunked
              two-phase) | recursive_doubling | xla
  reduce_scatter : pip_mcoll (two-level) | xla
  alltoall  : pip_mcoll (two-level multi-lane) | pip_pipeline (segmented) | xla

Large-message pipelining (the paper's segmented-transfer claim): algorithms
listed in :data:`CHUNKED` accept a ``chunks`` knob that splits the payload
into segments with *independent* per-segment collective chains, so the XLA
scheduler overlaps segment k's later phase with segment k+1's earlier phase
(send segment k while receiving segment k+1). ``chunks=1`` is the unchunked
algorithm; the selection subsystem picks the chunk count per size bucket
(``core.autotune``) and the analytic optimum lives in ``core.costmodel``.

Error-bounded compression (the C-Coll axis): algorithms listed in
:data:`COMPRESSED` accept a ``codec`` knob (registry in
``core.compress``). The compressed execution encodes the payload *before*
the slow wire axis — the ``node`` axis when present, else the ``local``
axis — and decodes/reduces after, so only the fast intra staging moves
uncompressed bytes. ``codec="none"`` is the lossless algorithm; the
selection subsystem admits lossy codecs only under the caller's
``error_budget``. The compressed allreduce additionally threads
**error-feedback state** (``err=``) so gradient consumers keep converging;
it composes with ``chunks`` (compressed segments pipeline independently).
Compressed broadcast/scatter use the **root-encodes-once** wire form: the
root encodes, the multi-object tree forwards the codec's wire form
leafwise, and only receivers decode — completing the codec matrix over
every collective.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compress as _codecs
from repro.core.topology import Topology

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _axes(topo: Topology) -> Tuple[str, ...]:
    """The topology's mesh axes with size > 1 (falls back to the local axis
    so a 1x1 topology still names a valid axis).

    Dropping size-1 axes preserves flat (node, local) rank order, and lets a
    degenerate topology (e.g. a ``1 x TP`` sub-communicator group) name a
    node axis that does not exist in the enclosing mesh. Delegates to
    :meth:`Topology.active_axes` (one definition of "active").
    """
    return topo.active_axes


def mo_rounds(n_nodes: int, radix: int) -> Sequence[int]:
    """Step sizes S for the multi-object Bruck schedule (paper steps 2-5).

    Full rounds while ``S * B <= N`` then one remainder round. Returns the
    list of S values, one ppermute round each.
    """
    out, s = [], 1
    while s < n_nodes:
        out.append(s)
        s += min((radix - 1) * s, n_nodes - s)
    return out


def _mo_perm(topo: Topology, step: int, n_lanes: int) -> list:
    """Static flat perm for one multi-object round: lane l of node n sends to
    node (n - (l+1)*step) % N (so it *receives* from (n + (l+1)*step) % N)."""
    N = topo.n_nodes
    pairs = []
    for n in range(N):
        for l in range(n_lanes):
            dst = ((n - (l + 1) * step) % N)
            pairs.append((topo.flat(n, l), topo.flat(dst, l)))
    return pairs


def _flat_shift_perm(topo: Topology, dist: int) -> list:
    """Flat perm over all M devices: rank r sends to (r - dist) % M."""
    M = topo.world
    return [(r, (r - dist) % M) for r in range(M)]


def _pad_to(x, mult):
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x, pad


def _norm_chunks(chunks, limit) -> int:
    """Static chunk count clamped to [1, limit]: a segment must hold at
    least one element, and SPMD shapes are static so the clamp happens at
    trace time."""
    return max(1, min(int(chunks), max(1, int(limit))))


def _segments(x, chunks: int, mult: int = 1, axis: int = 0):
    """Split ``axis`` into ``chunks`` equal segments, zero-padding so every
    segment length is a multiple of ``mult``. Returns (segments, seg_len).

    Equal static segment shapes keep the per-segment collective chains
    identical programs (one compiled body, ``chunks`` independent issues);
    callers slice the concatenated result back to the original length.
    """
    per = -(-x.shape[axis] // chunks)       # ceil
    per += (-per) % mult                    # round up to the level multiple
    pad = per * chunks - x.shape[axis]
    if pad:
        shape = x.shape[:axis] + (pad,) + x.shape[axis + 1:]
        x = jnp.concatenate([x, jnp.zeros(shape, x.dtype)], axis)
    return [lax.dynamic_slice_in_dim(x, k * per, per, axis=axis)
            for k in range(chunks)], per


# ---------------------------------------------------------------------------
# compressed execution (codec= on the COMPRESSED algorithms)
# ---------------------------------------------------------------------------
#
# The wire axis is the slow one: ``node`` when the topology has >1 node,
# else ``local``. Payloads are encoded into the codec's wire form (a dict of
# arrays with a leading per-peer axis) and the inter exchange runs leafwise
# over that form — int8/uint8/int32 leaves cross the wire, fp32 never does.
# The fast axis (when distinct) stages losslessly, exactly like the
# uncompressed two-level algorithms.


def _check_codec_payload(x, codec: str, collective: Optional[str] = None
                         ) -> None:
    """Two-way codec/payload domain check (see ``compress.admissible``):
    lossy codecs never touch integer payloads, and integer-only codecs
    never touch float payloads or reducing collectives."""
    cm = _codecs.meta(codec)
    dtype = jnp.asarray(x).dtype
    integer = jnp.issubdtype(dtype, jnp.integer)
    if cm.integer_only:
        if not integer:
            raise ValueError(
                f"integer-only codec {codec!r} on float payload dtype "
                f"{dtype}: its lossless claim holds only for integer "
                f"payloads")
        if collective in _codecs.REDUCING:
            raise ValueError(
                f"integer-only codec {codec!r} on reducing collective "
                f"{collective!r}: its wire form is not additive")
    elif integer and not cm.lossless:
        raise ValueError(
            f"lossy codec {codec!r} on integer payload dtype "
            f"{dtype}: integer collectives must stay "
            f"lossless (codec='none')")


def _wire_axis(topo: Topology) -> Tuple[Optional[str], int]:
    """(axis, size) of the slow axis compression targets: the node axis when
    present, else the local axis; (None, 1) on a 1x1 topology."""
    if topo.n_nodes > 1:
        return topo.node_axis, topo.n_nodes
    if topo.n_local > 1:
        return topo.local_axis, topo.n_local
    return None, 1


def _wire_all_to_all(comp, axis: str):
    """Leafwise all-to-all of a wire form over the wire axis (leading dim =
    per-peer slices): slice i of every peer lands on peer i."""
    return jax.tree.map(
        lambda a: lax.all_to_all(a, axis, split_axis=0, concat_axis=0,
                                 tiled=False), comp)


def _wire_all_gather(comp, axis: str):
    """Leafwise all-gather of a wire form over the wire axis (tiled on the
    leading per-peer dim)."""
    return jax.tree.map(
        lambda a: lax.all_gather(a, axis, axis=0, tiled=True), comp)


def _compressed_allreduce(x, topo: Topology, codec: str, err=None):
    """Two-level compressed allreduce with optional error feedback.

    Phases: (1) lossless intra reduce-scatter over the fast axis (each lane
    owns 1/P of the vector); (2) the slice splits into W wire sub-slices,
    **encoded** and exchanged reduce-scatter-style over the wire axis;
    (3) decode + sum + re-encode; (4) encoded allgather back over the wire
    axis, decode; (5) lossless intra allgather. Only codec wire forms cross
    the slow axis.

    ``err`` (optional, shape/size of ``x``): error-feedback state. Each
    device adds its carried residual before compressing and gets back the
    fresh residual of what *it* quantized this call (both encode sites),
    placed at the positions it owns post-scatter — summed exactly once into
    the next call's reduction. Returns ``(out, new_err)`` when given.
    """
    cd = _codecs.codec(codec)
    _check_codec_payload(x, codec, "allreduce")
    dtype = x.dtype
    shape = x.shape
    wire, W = _wire_axis(topo)
    if wire is None:
        out = x
        return (out, err) if err is not None else out
    fast = topo.local_axis if (topo.n_nodes > 1 and topo.n_local > 1) \
        else None
    Pl = topo.n_local if fast else 1
    # allreduce is elementwise: flatten trailing dims so the slice/encode
    # arithmetic is 1-D (the lossless paths keep trailing dims; results
    # reshape back at the end)
    g = x.astype(jnp.float32).reshape(-1)
    orig = g.shape[0]
    if err is not None:
        g = g + err.astype(jnp.float32).reshape(-1)
    gp, _ = _pad_to(g, Pl)
    if fast:
        s = lax.psum_scatter(gp, fast, scatter_dimension=0, tiled=True)
        my_off = lax.axis_index(fast) * s.shape[0]
    else:
        s = gp
        my_off = 0
    Lp = s.shape[0]
    Ls = -(-Lp // W)
    sp, _ = _pad_to(s, W * Ls)
    xs = sp.reshape(W, Ls)
    # fused-capable encode sites: the codec emits wire form + round-trip
    # residual in one pass (kernels/codec.py) instead of a decode round trip
    if err is not None:
        comp, r1 = cd.encode_residual(xs)
    else:
        comp = cd.encode(xs)
    # reduce-scatter over the wire: peer w receives sub-slice w of everyone;
    # decode_reduce accumulates the W wire slices without materializing the
    # dequantized (W, Ls) intermediate
    mine = cd.decode_reduce(_wire_all_to_all(comp, wire), Ls)
    if err is not None:
        comp2, r2s = cd.encode_residual(mine[None])
        r2 = r2s[0]
    else:
        comp2 = cd.encode(mine[None])
    red = cd.decode(_wire_all_gather(comp2, wire), Ls).reshape(-1)[:Lp]
    out = lax.all_gather(red, fast, axis=0, tiled=True) if fast else red
    out = out[:orig].astype(dtype).reshape(shape)
    if err is None:
        return out
    # place both residuals at the positions this device owns: r1 covers the
    # whole scattered slice; r2 belongs to the wire sub-slice it reduced
    res = r1.reshape(-1)
    w0 = lax.axis_index(wire)
    seg = lax.dynamic_slice_in_dim(res, w0 * Ls, Ls) + r2
    res = lax.dynamic_update_slice_in_dim(res, seg, w0 * Ls, axis=0)[:Lp]
    new_err = jnp.zeros((gp.shape[0],), jnp.float32)
    new_err = lax.dynamic_update_slice_in_dim(new_err, res, my_off, axis=0)
    return out, new_err[:orig].reshape(jnp.shape(err))


def _compressed_reduce_scatter(x, topo: Topology, codec: str):
    """Wire-axis compressed reduce-scatter, then lossless intra scatter.

    Mirrors the lossless two-level order (nodes first): each device encodes
    its W wire sub-slices, the wire all-to-all delivers sub-slice w to wire
    peer w, decode + sum reduces over the wire axis, and a lossless intra
    psum_scatter finishes the reduction over the fast axis."""
    cd = _codecs.codec(codec)
    _check_codec_payload(x, codec, "reduce_scatter")
    dtype = x.dtype
    wire, W = _wire_axis(topo)
    if wire is None:
        return x
    fast = topo.local_axis if (topo.n_nodes > 1 and topo.n_local > 1) \
        else None
    rows = x.shape[0]
    if rows % topo.world:
        raise ValueError(f"reduce_scatter payload dim0 {rows} must be "
                         f"divisible by world size {topo.world}")
    # rank chunks are contiguous dim0 row blocks, so flattening trailing
    # dims (row-major) keeps chunk boundaries aligned for the 1-D slice
    # arithmetic; the output reshapes back to (rows/world, ...)
    flat = x.astype(jnp.float32).reshape(-1)
    Ls = flat.shape[0] // W
    xs = flat.reshape(W, Ls)
    comp = cd.encode(xs)
    mine = cd.decode_reduce(_wire_all_to_all(comp, wire), Ls)
    if fast:
        mine = lax.psum_scatter(mine, fast, scatter_dimension=0, tiled=True)
    return mine.astype(dtype).reshape((rows // topo.world,) + x.shape[1:])


def _compressed_allgather(x, topo: Topology, codec: str):
    """Lossless intra gather into the node block, encoded allgather over
    the wire axis, decode. Node-major order needs no final shift.

    The payload reaches ``encode`` in its own dtype (every codec casts
    internally) — integer-only codecs keep integer payloads off the f32
    path, so values above 2**24 survive the trip."""
    cd = _codecs.codec(codec)
    _check_codec_payload(x, codec, "allgather")
    dtype = x.dtype
    wire, W = _wire_axis(topo)
    if wire is None:
        return x
    fast = topo.local_axis if (topo.n_nodes > 1 and topo.n_local > 1) \
        else None
    nodeblk = lax.all_gather(x, fast, axis=0, tiled=True) if fast else x
    flat = nodeblk.reshape(1, -1)
    L = flat.shape[1]
    out = cd.decode(_wire_all_gather(cd.encode(flat), wire), L)
    return out.reshape((W * nodeblk.shape[0],)
                       + nodeblk.shape[1:]).astype(dtype)


def _compressed_alltoall(x, topo: Topology, codec: str):
    """Hierarchical all-to-all with the wire exchange compressed: the intra
    regroup (when both axes exist) stays lossless, the per-node payloads
    encode before the node-axis exchange and decode after."""
    cd = _codecs.codec(codec)
    _check_codec_payload(x, codec, "alltoall")
    dtype = x.dtype
    N, Pl = topo.n_nodes, topo.n_local
    s = x.shape[1:]
    if N * Pl == 1:
        return x
    if N > 1:
        v = x.reshape((N, Pl) + s)
        if Pl > 1:
            v = lax.all_to_all(v, topo.local_axis, split_axis=1,
                               concat_axis=1, tiled=False)
        flat = v.reshape(N, -1)
        out = cd.decode(_wire_all_to_all(cd.encode(flat), topo.node_axis),
                        flat.shape[1])
        return out.reshape((N * Pl,) + s).astype(dtype)
    flat = x.reshape(Pl, -1)
    out = cd.decode(_wire_all_to_all(cd.encode(flat), topo.local_axis),
                    flat.shape[1])
    return out.reshape((Pl,) + s).astype(dtype)


def _compressed_broadcast(x, topo: Topology, codec: str,
                          radix: Optional[int], root: int):
    """Root-encodes-once compressed broadcast: encode the payload into the
    codec's wire form, run the multi-object broadcast tree **leafwise over
    the wire form** (non-root copies are zero-masked exactly like the
    lossless tree, so only the root's encoding propagates), decode at every
    receiver. One encode + one decode per device, regardless of tree depth
    — every device's output is bitwise ``decode(encode(x))`` of the root's
    payload, which conformance asserts as the wire-form invariant."""
    cd = _codecs.codec(codec)
    _check_codec_payload(x, codec, "broadcast")
    dtype = x.dtype
    shape = x.shape
    flat = x.reshape(1, -1)
    L = flat.shape[1]
    comp = jax.tree.map(lambda a: _broadcast_tree(a, topo, radix, root),
                        cd.encode(flat))
    return cd.decode(comp, L).reshape(shape).astype(dtype)


def _compressed_scatter(xfull, topo: Topology, codec: str,
                        radix: Optional[int], root: int):
    """Root-encodes-once compressed scatter: the root encodes the ``M``
    per-destination slices into one wire form (leading dim M), the
    multi-object scatter tree forwards the wire form leafwise — each
    subtree receives only its destinations' encoded slices — and every
    device decodes just its own slice. Device d's output is bitwise row d
    of ``decode(encode(full))``."""
    cd = _codecs.codec(codec)
    _check_codec_payload(xfull, codec, "scatter")
    dtype = xfull.dtype
    M = topo.world
    m = xfull.shape[0] // M
    flat = xfull.reshape(M, -1)
    L = flat.shape[1]
    mine = jax.tree.map(lambda a: _scatter_tree(a, topo, radix, root),
                        cd.encode(flat))
    return cd.decode(mine, L).reshape((m,) + xfull.shape[1:]).astype(dtype)


# ---------------------------------------------------------------------------
# ALLGATHER
# ---------------------------------------------------------------------------


def pip_mcoll_allgather(x, topo: Topology, radix: Optional[int] = None,
                        shift_fn=None, codec: str = "none"):
    """The paper's multi-object allgather (Section 2), TPU-native.

    Per-device input: ``(m, ...)`` shard. Output: ``(N*P*m, ...)`` full
    gather in global (node-major) rank order, identical on every device.

    Phases: (1) intra all_gather — the PiP "gather into the local root's
    buffer" (on TPU every lane keeps a copy: it must send in phase 2);
    (2) ``ceil(log_B N)``-ish rounds, each ONE collective-permute over the
    (node, local) tuple axis moving S node-blocks per lane + one intra
    all_gather (the PiP shared-buffer write); (3) final shift (paper step 6)
    — ``jnp.roll`` by the node index, or a Pallas shift kernel.

    ``codec != "none"`` switches to the compressed execution: the node
    block is encoded once and only the codec's wire form crosses the slow
    axis (see :func:`_compressed_allgather`).
    """
    if codec != "none":
        return _compressed_allgather(x, topo, codec)
    N, Pl = topo.n_nodes, topo.n_local
    B = int(radix) if radix else Pl + 1
    if not 2 <= B <= Pl + 1:
        raise ValueError(f"radix {B} must be in [2, P+1={Pl + 1}]")
    nodeblk = lax.all_gather(x, topo.local_axis, axis=0, tiled=True)  # (P*m,...)
    if N == 1:
        return nodeblk
    n = lax.axis_index(topo.node_axis)
    # V[j] = node-block of node (n + j) % N, for j < S; identical on all
    # lanes of a node (the shared-memory invariant).
    V = nodeblk[None]  # (1, P*m, ...)
    S = 1
    while S < N:
        K = min((B - 1) * S, N - S)  # fresh node-blocks this round
        # only lanes carrying useful offsets participate (matters when
        # (B-1)*S > N-S: remainder round / tiny N), and when a single lane
        # remains it sends exactly the K useful blocks, not a padded S.
        n_lanes = min(B - 1, -(-K // S))
        send_cnt = min(S, K)
        perm = _mo_perm(topo, S, n_lanes=n_lanes)
        recv = lax.ppermute(V[:send_cnt], _axes(topo), perm)
        # lane l received offsets (l+1)*S + [0, send_cnt)
        shared = lax.all_gather(recv, topo.local_axis, axis=0, tiled=False)
        shared = shared.reshape((Pl * send_cnt,) + V.shape[1:])
        V = jnp.concatenate([V, shared[:K]], axis=0)
        S += K
    # paper step 6: shift into correct sequence. V[j] = block (n+j)%N, so
    # roll by +n gives W[k] = block k.
    if shift_fn is not None:
        W = shift_fn(V, n)
    else:
        W = jnp.roll(V, n, axis=0)
    return W.reshape((N * Pl * x.shape[0],) + x.shape[1:])


def bruck_allgather(x, topo: Topology, radix: int = 2):
    """Flat Bruck over all M = N*P ranks (the paper's "PiP-MPICH" baseline
    when radix=2: log2(M) rounds, every rank a single object)."""
    M = topo.world
    r = lax.axis_index(_axes(topo))
    V = x[None]  # (1, m, ...)
    S = 1
    while S < M:
        for j in range(1, radix):
            if j * S >= M:
                break
            cnt = min(S, M - j * S)  # uniform across ranks
            perm = _flat_shift_perm(topo, j * S)
            # perm maps rank i -> i - j*S, so we receive from r + j*S whose
            # V[0:cnt] holds blocks at our offsets j*S + [0, cnt).
            recv = lax.ppermute(V[:cnt], _axes(topo), perm)
            V = jnp.concatenate([V, recv], axis=0)
        S *= radix
    V = V[:M]
    W = jnp.roll(V, r, axis=0)
    return W.reshape((M * x.shape[0],) + x.shape[1:])


def recursive_doubling_allgather(x, topo: Topology):
    """Flat recursive doubling (power-of-two M only) — classic small-message
    algorithm the paper compares against."""
    M = topo.world
    if M & (M - 1):
        raise ValueError("recursive doubling needs power-of-two world size")
    r = lax.axis_index(_axes(topo))
    V = x[None]
    S = 1
    while S < M:
        perm = [(i, i ^ S) for i in range(M)]
        recv = lax.ppermute(V, _axes(topo), perm)
        bit = ((r // S) % 2).astype(jnp.bool_)
        # bit==0: my group is the lower half -> my blocks come first
        both = jnp.stack([jnp.concatenate([V, recv], axis=0),
                          jnp.concatenate([recv, V], axis=0)])
        V = jnp.where(bit, both[1], both[0])
        S *= 2
    return V.reshape((M * x.shape[0],) + x.shape[1:])


def ring_allgather(x, topo: Topology):
    """Flat ring: M-1 rounds, bandwidth-optimal, latency-worst."""
    M = topo.world
    r = lax.axis_index(_axes(topo))
    perm = _flat_shift_perm(topo, -1)  # r sends to r+1, receives from r-1
    collected = [x]
    cur = x
    for _ in range(M - 1):
        cur = lax.ppermute(cur, _axes(topo), perm)
        collected.append(cur)
    S = jnp.stack(collected)  # S[i] = block of rank (r - i) % M
    idx = (r - jnp.arange(M)) % M
    W = jnp.take(S, idx, axis=0)  # W[k] = block of rank k
    return W.reshape((M * x.shape[0],) + x.shape[1:])


def ring_pipeline_allgather(x, topo: Topology, chunks: int = 1):
    """Segmented ring allgather: the block is split into ``chunks`` segments
    with an independent ring chain each, so round r of segment k overlaps
    round r+1 of segment k-1 (each lane sends segment k while receiving
    segment k+1). ``chunks=1`` degenerates to the plain ring.

    Bandwidth-optimal like the ring, but the pipeline hides all but one
    round's latency behind the wire time of the other segments — the
    large-message regime the paper's segmented transfers target.
    """
    M = topo.world
    m = x.shape[0]
    c = _norm_chunks(chunks, m)
    r = lax.axis_index(_axes(topo))
    perm = _flat_shift_perm(topo, -1)  # r sends to r+1, receives from r-1
    segs, per = _segments(x, c)
    rows = [jnp.concatenate(segs, axis=0)]  # own (padded) block
    cur = segs
    for _ in range(M - 1):
        # one ppermute per segment per round: independent chains, so XLA
        # may issue segment k+1's send while segment k's recv is in flight
        cur = [lax.ppermute(s, _axes(topo), perm) for s in cur]
        rows.append(jnp.concatenate(cur, axis=0))
    S = jnp.stack(rows)  # S[i] = padded block of rank (r - i) % M
    idx = (r - jnp.arange(M)) % M
    W = jnp.take(S, idx, axis=0)  # W[k] = padded block of rank k
    return W[:, :m].reshape((M * m,) + x.shape[1:])


def single_leader_allgather(x, topo: Topology):
    """Single-object hierarchical baseline (OpenMPI-style): intra gather to a
    leader, leader-only radix-2 Bruck over nodes, intra broadcast. On TPU the
    SPMD program runs the node-axis Bruck on every lane; the cost model
    charges only the leader lane."""
    N, Pl = topo.n_nodes, topo.n_local
    nodeblk = lax.all_gather(x, topo.local_axis, axis=0, tiled=True)
    if N == 1:
        return nodeblk
    n = lax.axis_index(topo.node_axis)
    V = nodeblk[None]
    S = 1
    while S < N:
        cnt = min(S, N - S)
        perm = [(i, (i - S) % N) for i in range(N)]
        recv = lax.ppermute(V[:cnt], topo.node_axis, perm)
        V = jnp.concatenate([V, recv], axis=0)
        S += cnt
    W = jnp.roll(V, n, axis=0)
    return W.reshape((N * Pl * x.shape[0],) + x.shape[1:])


def xla_allgather(x, topo: Topology):
    return lax.all_gather(x, _axes(topo), axis=0, tiled=True)


ALLGATHER = {
    "pip_mcoll": pip_mcoll_allgather,
    "bruck": bruck_allgather,
    "recursive_doubling": recursive_doubling_allgather,
    "ring": ring_allgather,
    "ring_pipeline": ring_pipeline_allgather,
    "single_leader": single_leader_allgather,
    "xla": xla_allgather,
}


# ---------------------------------------------------------------------------
# SCATTER (paper Figure 1 collective)
# ---------------------------------------------------------------------------


def pip_mcoll_scatter(xfull, topo: Topology, radix: Optional[int] = None,
                      root: int = 0, chunks: int = 1, codec: str = "none"):
    """Multi-object scatter: radix-(P+1) binomial tree over nodes in which an
    active node's P lanes feed P distinct child nodes *in the same round*,
    then a free intra-node slice (PiP shared memory analogue).

    ``xfull``: full payload ``(N*P*m, ...)`` (only the root's copy is
    semantically read; other nodes' buffers are zeroed to prove data flow).
    Output: this device's ``(m, ...)`` shard.

    ``chunks > 1`` segments every rank's payload and runs an independent
    tree per segment, so a lane sends segment k down the tree while
    receiving segment k+1 (pipelined large-message scatter).

    ``codec != "none"`` switches to the compressed execution: the root
    encodes its per-destination slices once and the tree forwards the wire
    form (see :func:`_compressed_scatter`); compressed segments pipeline
    independently.
    """
    M = topo.world
    if xfull.shape[0] % M:
        raise ValueError(f"scatter payload dim0 {xfull.shape[0]} must be "
                         f"divisible by world size {M}")
    m = xfull.shape[0] // M
    c = _norm_chunks(chunks, m)
    if codec != "none":
        def body(seg):
            return _compressed_scatter(seg, topo, codec, radix, root)
    else:
        def body(seg):
            return _scatter_tree(seg, topo, radix, root)
    if c > 1:
        blocks = xfull.reshape((M, m) + xfull.shape[1:])
        segs, per = _segments(blocks, c, axis=1)
        outs = [body(s.reshape((M * per,) + xfull.shape[1:])) for s in segs]
        return jnp.concatenate(outs, axis=0)[:m]
    return body(xfull)


def _scatter_tree(xfull, topo: Topology, radix: Optional[int], root: int):
    """One unsegmented multi-object scatter tree (the chunks=1 body)."""
    N, Pl = topo.n_nodes, topo.n_local
    B = int(radix) if radix else Pl + 1
    M = topo.world
    m = xfull.shape[0] // M
    root_node, root_lane = divmod(root, Pl)
    n = lax.axis_index(topo.node_axis)
    l = lax.axis_index(topo.local_axis)
    v = (n - root_node) % N  # relative node id; root is v=0
    blocks = xfull.reshape((N, Pl * m) + xfull.shape[1:])
    # R[j] = node-block for relative node j; valid only on the root initially.
    R = jnp.roll(blocks, -root_node, axis=0)
    R = jnp.where((v == 0), R, jnp.zeros_like(R))
    if N > 1:
        # exact ceil(log_B N) by integer arithmetic (float log is
        # off-by-precision for exact powers, costing a spurious round)
        n_rounds, cap = 1, B
        while cap < N:
            cap *= B
            n_rounds += 1
        # pad to the tree capacity so every dynamic_slice send window
        # [(l+1)S, (l+2)S) is in-bounds (SPMD needs uniform static sizes).
        if cap > N:
            R = jnp.concatenate(
                [R, jnp.zeros((cap - N,) + R.shape[1:], R.dtype)], axis=0)
        steps = [B ** i for i in range(n_rounds - 1, -1, -1)]
        for S in steps:
            pairs = []
            for va in range(0, N, S * B):
                for lane in range(Pl):
                    tgt = va + (lane + 1) * S
                    if tgt < min(va + S * B, N):
                        pairs.append((topo.flat((va + root_node) % N, lane),
                                      topo.flat((tgt + root_node) % N, lane)))
            if not pairs:
                continue
            # every device computes a send buffer; only perm sources are used
            start = (l + 1) * S
            send = lax.dynamic_slice_in_dim(R, start, S, axis=0)
            recv = lax.ppermute(send, _axes(topo), pairs)
            # exactly one lane per receiving node is a destination; share it
            # (the PiP write into the node's shared buffer).
            is_dst = (v % S == 0) & ((v // S) % B == l + 1)
            seg = lax.psum(jnp.where(is_dst, recv, jnp.zeros_like(recv)),
                           topo.local_axis)
            got = lax.psum(is_dst.astype(jnp.int32), topo.local_axis) > 0
            R = R.at[:S].set(jnp.where(got, seg, R[:S]))
    # intra scatter: lane l takes slice l of the node block (pure local copy)
    return lax.dynamic_slice_in_dim(R[0], l * m, m, axis=0)


def binomial_scatter(xfull, topo: Topology, root: int = 0):
    """Classic radix-2 binomial scatter over the flat rank space (baseline:
    log2(M) rounds, single object per node)."""
    M = topo.world
    m = xfull.shape[0] // M
    r = lax.axis_index(_axes(topo))
    v = (r - root) % M
    blocks = xfull.reshape((M, m) + xfull.shape[1:])
    R = jnp.roll(blocks, -root, axis=0)
    R = jnp.where(v == 0, R, jnp.zeros_like(R))
    S = 1
    while S < M:
        S *= 2
    if S > M:  # pad to power-of-two capacity for in-bounds slice windows
        R = jnp.concatenate(
            [R, jnp.zeros((S - M,) + R.shape[1:], R.dtype)], axis=0)
    S //= 2
    while S >= 1:
        pairs = []
        for va in range(0, M, S * 2):
            tgt = va + S
            if tgt < M:
                pairs.append((((va + root) % M), ((tgt + root) % M)))
        if pairs:
            send = lax.dynamic_slice_in_dim(R, S, S, axis=0)
            recv = lax.ppermute(send, _axes(topo), pairs)
            is_dst = (v % S == 0) & ((v // S) % 2 == 1)
            R = R.at[:S].set(jnp.where(is_dst, recv, R[:S]))
        S //= 2
    return R[0]


def linear_scatter(xfull, topo: Topology, root: int = 0):
    """Root sends to every rank directly (M-1 serial messages) — the naive
    baseline; on TPU realized as one masked select from the replicated input."""
    M = topo.world
    m = xfull.shape[0] // M
    r = lax.axis_index(_axes(topo))
    blocks = xfull.reshape((M, m) + xfull.shape[1:])
    return jnp.take(blocks, r[None], axis=0)[0]


SCATTER = {
    "pip_mcoll": pip_mcoll_scatter,
    "binomial": binomial_scatter,
    "linear": linear_scatter,
}


# ---------------------------------------------------------------------------
# BROADCAST
# ---------------------------------------------------------------------------


def pip_mcoll_broadcast(x, topo: Topology, radix: Optional[int] = None,
                        root: int = 0, chunks: int = 1, codec: str = "none"):
    """Multi-object broadcast: radix-(P+1) tree over nodes (active node's P
    lanes feed P children per round) + free intra share.

    ``chunks > 1`` segments the payload along dim0 and runs an independent
    tree per segment (each round's lane sends segment k while receiving
    segment k+1 — the pipelined large-message variant).

    ``codec != "none"`` switches to the compressed execution: the root
    encodes once and the tree forwards the wire form (see
    :func:`_compressed_broadcast`); compressed segments pipeline
    independently."""
    c = _norm_chunks(chunks, x.shape[0] if x.ndim else 1)
    if codec != "none":
        def body(seg):
            return _compressed_broadcast(seg, topo, codec, radix, root)
    else:
        def body(seg):
            return _broadcast_tree(seg, topo, radix, root)
    if c > 1:
        m = x.shape[0]
        segs, _ = _segments(x, c)
        outs = [body(s) for s in segs]
        return jnp.concatenate(outs, axis=0)[:m]
    return body(x)


def _broadcast_tree(x, topo: Topology, radix: Optional[int], root: int):
    """One unsegmented multi-object broadcast tree (the chunks=1 body)."""
    N, Pl = topo.n_nodes, topo.n_local
    B = int(radix) if radix else Pl + 1
    root_node, _ = divmod(root, Pl)
    n = lax.axis_index(topo.node_axis)
    l = lax.axis_index(topo.local_axis)
    v = (n - root_node) % N
    R = jnp.where(v == 0, x, jnp.zeros_like(x))
    if N > 1:
        n_rounds, cap = 1, B
        while cap < N:
            cap *= B
            n_rounds += 1
        steps = [B ** i for i in range(n_rounds - 1, -1, -1)]
        for S in steps:
            pairs = []
            for va in range(0, N, S * B):
                for lane in range(Pl):
                    tgt = va + (lane + 1) * S
                    if tgt < min(va + S * B, N):
                        pairs.append((topo.flat((va + root_node) % N, lane),
                                      topo.flat((tgt + root_node) % N, lane)))
            if not pairs:
                continue
            recv = lax.ppermute(R, _axes(topo), pairs)
            is_dst = (v % S == 0) & ((v // S) % B == l + 1)
            seg = lax.psum(jnp.where(is_dst, recv, jnp.zeros_like(recv)),
                           topo.local_axis)
            got = lax.psum(is_dst.astype(jnp.int32), topo.local_axis) > 0
            R = jnp.where(got, seg, R)
    return R


def binomial_broadcast(x, topo: Topology, root: int = 0):
    M = topo.world
    r = lax.axis_index(_axes(topo))
    v = (r - root) % M
    R = jnp.where(v == 0, x, jnp.zeros_like(x))
    S = 1
    while S < M:
        S *= 2
    S //= 2
    while S >= 1:
        pairs = []
        for va in range(0, M, S * 2):
            tgt = va + S
            if tgt < M:
                pairs.append((((va + root) % M), ((tgt + root) % M)))
        if pairs:
            recv = lax.ppermute(R, _axes(topo), pairs)
            is_dst = (v % S == 0) & ((v // S) % 2 == 1)
            R = jnp.where(is_dst, recv, R)
        S //= 2
    return R


def xla_broadcast(x, topo: Topology, root: int = 0):
    """Vendor broadcast realized as a psum mask: every copy except the
    root's is zeroed, then one vendor allreduce propagates the root's value.
    Real data flow from the root (an identity on the replicated operand
    would neither reconcile divergent replicas nor time honestly in
    calibration)."""
    r = lax.axis_index(_axes(topo))
    return lax.psum(jnp.where(r == root, x, jnp.zeros_like(x)), _axes(topo))


BROADCAST = {
    "pip_mcoll": pip_mcoll_broadcast,
    "binomial": binomial_broadcast,
    "xla": xla_broadcast,
}


# ---------------------------------------------------------------------------
# ALLREDUCE / REDUCE_SCATTER
# ---------------------------------------------------------------------------


def pip_mcoll_allreduce(x, topo: Topology, inter: str = "psum",
                        codec: str = "none", err=None):
    """Two-level multi-object allreduce: intra reduce-scatter (each lane owns
    1/P of the vector) -> per-lane inter allreduce over nodes (all P lanes
    drive inter links concurrently on disjoint slices) -> intra allgather.

    This is the multi-object Rabenseifner split: same round count as a flat
    algorithm but P-fold smaller inter-node messages and all lanes busy.

    ``codec != "none"`` switches to the compressed execution (wire-axis
    traffic in codec form, optional ``err`` error-feedback state — then the
    return value is ``(out, new_err)``); see :func:`_compressed_allreduce`.
    """
    if codec != "none" or err is not None:
        return _compressed_allreduce(x, topo, codec, err)
    N, Pl = topo.n_nodes, topo.n_local
    orig = x.shape[0]
    xp, _ = _pad_to(x, Pl)
    slice_ = lax.psum_scatter(xp, topo.local_axis, scatter_dimension=0,
                              tiled=True)
    if N > 1:
        if inter == "psum":
            slice_ = lax.psum(slice_, topo.node_axis)
        elif inter == "recursive_doubling":
            slice_ = _rd_allreduce_axis(slice_, topo, topo.node_axis, N)
        else:
            raise ValueError(inter)
    out = lax.all_gather(slice_, topo.local_axis, axis=0, tiled=True)
    return out[:orig]


def _rd_allreduce_axis(x, topo: Topology, axis: str, size: int):
    """Manual recursive-doubling allreduce along one mesh axis (power of 2)."""
    if size & (size - 1):
        return lax.psum(x, axis)
    S = 1
    while S < size:
        perm = [(i, i ^ S) for i in range(size)]
        x = x + lax.ppermute(x, axis, perm)
        S *= 2
    return x


def pip_pipeline_allreduce(x, topo: Topology, chunks: int = 1,
                           codec: str = "none", err=None):
    """Pipelined two-phase allreduce: the vector is split into ``chunks``
    segments; each segment runs an independent two-level reduce-scatter
    (nodes, then lanes) followed by the mirrored two-level allgather.

    The per-segment chains carry no cross-segment data dependence, so the
    scheduler overlaps segment k's allgather with segment k+1's
    reduce-scatter — the paper's segmented-transfer overlap of intra- and
    inter-node stages. ``chunks=1`` is the plain two-phase (Rabenseifner)
    split; the chunk count is a tuning knob the selection subsystem picks
    per size bucket (analytic optimum in ``core.costmodel``).

    ``codec != "none"`` composes compression with pipelining: each segment
    is independently encoded and runs its own compressed two-level chain
    (segment k's wire allgather overlaps segment k+1's encode + wire
    reduce-scatter). ``err`` (optional, shaped like ``x``) threads
    error-feedback state through the per-segment encoders — the return
    value is then ``(out, new_err)``."""
    orig = x.shape[0]
    M = topo.world
    # a segment must span all M ranks after the reduce-scatter split:
    # clamping to orig // M keeps the mult-of-M rounding from amplifying
    # the communicated volume when chunks is over-asked for a small vector
    c = _norm_chunks(chunks, orig // M)
    if codec != "none" or err is not None:
        segs, per = _segments(x, c, mult=M)
        if err is not None:
            err_segs, _ = _segments(err.astype(jnp.float32), c, mult=M)
            pairs = [_compressed_allreduce(sg, topo, codec, eg)
                     for sg, eg in zip(segs, err_segs)]
            out = jnp.concatenate([p[0] for p in pairs], axis=0)[:orig]
            new_err = jnp.concatenate([p[1] for p in pairs], axis=0)[:orig]
            return out, new_err
        outs = [_compressed_allreduce(sg, topo, codec) for sg in segs]
        return jnp.concatenate(outs, axis=0)[:orig]
    segs, _ = _segments(x, c, mult=M)
    outs = []
    for seg in segs:
        y = seg
        # reduce-scatter: nodes first (big contiguous inter chunks, all
        # lanes active), then lanes; degenerate axes are skipped.
        if topo.n_nodes > 1:
            y = lax.psum_scatter(y, topo.node_axis, scatter_dimension=0,
                                 tiled=True)
        if topo.n_local > 1:
            y = lax.psum_scatter(y, topo.local_axis, scatter_dimension=0,
                                 tiled=True)
        # allgather mirrors back in reverse axis order
        if topo.n_local > 1:
            y = lax.all_gather(y, topo.local_axis, axis=0, tiled=True)
        if topo.n_nodes > 1:
            y = lax.all_gather(y, topo.node_axis, axis=0, tiled=True)
        outs.append(y)
    return jnp.concatenate(outs, axis=0)[:orig]


def flat_rd_allreduce(x, topo: Topology):
    """Flat recursive doubling over all M devices (single-object baseline)."""
    M = topo.world
    if M & (M - 1):
        return lax.psum(x, _axes(topo))
    S = 1
    while S < M:
        perm = [(i, i ^ S) for i in range(M)]
        x = x + lax.ppermute(x, _axes(topo), perm)
        S *= 2
    return x


def xla_allreduce(x, topo: Topology):
    return lax.psum(x, _axes(topo))


ALLREDUCE = {
    "pip_mcoll": pip_mcoll_allreduce,
    "pip_pipeline": pip_pipeline_allreduce,
    "recursive_doubling": flat_rd_allreduce,
    "xla": xla_allreduce,
}


def pip_mcoll_reduce_scatter(x, topo: Topology, codec: str = "none"):
    """Two-level reduce-scatter: over nodes first (big contiguous chunks on
    the inter links, all lanes active), then over lanes. Input per device
    ``(M*s, ...)``, output ``(s, ...)`` = this rank's reduced chunk.
    Degenerate levels are skipped (the axis may be absent from the mesh).

    ``codec != "none"`` encodes the per-node slices before the node-axis
    exchange (see :func:`_compressed_reduce_scatter`)."""
    if codec != "none":
        return _compressed_reduce_scatter(x, topo, codec)
    y = x
    if topo.n_nodes > 1:
        y = lax.psum_scatter(y, topo.node_axis, scatter_dimension=0,
                             tiled=True)
    if topo.n_local > 1:
        y = lax.psum_scatter(y, topo.local_axis, scatter_dimension=0,
                             tiled=True)
    return y


def xla_reduce_scatter(x, topo: Topology):
    return lax.psum_scatter(x, _axes(topo), scatter_dimension=0, tiled=True)


REDUCE_SCATTER = {
    "pip_mcoll": pip_mcoll_reduce_scatter,
    "xla": xla_reduce_scatter,
}


# ---------------------------------------------------------------------------
# ALLTOALL (MoE expert-parallel dispatch path)
# ---------------------------------------------------------------------------


def pip_mcoll_alltoall(x, topo: Topology, codec: str = "none"):
    """Hierarchical multi-object all-to-all: intra regroup so each lane
    carries 1/P of every node-pair payload, inter all-to-all per lane (all P
    lanes drive inter links concurrently), local reorder.

    Input per device: ``(M, s, ...)`` — row g is the payload for global rank
    g. Output: ``(M, s, ...)`` — row g is the payload received from rank g.

    ``codec != "none"`` encodes the per-node payloads before the node-axis
    exchange (see :func:`_compressed_alltoall`).
    """
    if codec != "none":
        return _compressed_alltoall(x, topo, codec)
    N, Pl = topo.n_nodes, topo.n_local
    s = x.shape[1:]
    v = x.reshape((N, Pl) + s)  # (dst_node, dst_lane, s...)
    # phase 1 (intra): exchange by destination lane; afterwards device (n,l)
    # holds rows destined to lane l of every node, from every source lane.
    # Degenerate levels are skipped entirely so the topology may name axes
    # absent from the mesh (e.g. a 1 x TP topology inside the MoE body).
    if Pl > 1:
        v = lax.all_to_all(v, topo.local_axis, split_axis=1, concat_axis=1,
                           tiled=False)
    # now v: (dst_node, src_lane, s...)
    # phase 2 (inter, multi-lane): exchange by destination node.
    if N > 1:
        v = lax.all_to_all(v, topo.node_axis, split_axis=0, concat_axis=0,
                           tiled=False)
    # now v: (src_node, src_lane, s...) — already (M, s) in flat order.
    return v.reshape((N * Pl,) + s)


def pip_pipeline_alltoall(x, topo: Topology, chunks: int = 1,
                          codec: str = "none"):
    """Segmented hierarchical all-to-all: the per-peer payload (axis 1) is
    split into ``chunks`` segments, each running an independent
    :func:`pip_mcoll_alltoall` chain — a lane ships segment k inter-node
    while segment k+1 is still in its intra regroup (the MoE large-dispatch
    variant). Rank-0-only payloads (``ndim < 2``) have no payload axis to
    segment and degrade to the unsegmented algorithm.

    ``codec != "none"`` compresses each segment's node-axis exchange
    independently (compressed segments pipeline independently)."""
    if x.ndim < 2:
        return pip_mcoll_alltoall(x, topo, codec=codec)
    s0 = x.shape[1]
    c = _norm_chunks(chunks, s0)
    if c == 1:
        return pip_mcoll_alltoall(x, topo, codec=codec)
    segs, _ = _segments(x, c, axis=1)
    outs = [pip_mcoll_alltoall(s, topo, codec=codec) for s in segs]
    return jnp.concatenate(outs, axis=1)[:, :s0]


def xla_alltoall(x, topo: Topology):
    return lax.all_to_all(x, _axes(topo), split_axis=0, concat_axis=0,
                          tiled=True)


ALLTOALL = {
    "pip_mcoll": pip_mcoll_alltoall,
    "pip_pipeline": pip_pipeline_alltoall,
    "xla": xla_alltoall,
}


# ---------------------------------------------------------------------------
# algorithm registry — construction of shard_map'd callables lives in
# repro.core.runtime (version portability + compiled-callable cache)
# ---------------------------------------------------------------------------

_REGISTRY = {
    "allgather": ALLGATHER,
    "scatter": SCATTER,
    "broadcast": BROADCAST,
    "allreduce": ALLREDUCE,
    "reduce_scatter": REDUCE_SCATTER,
    "alltoall": ALLTOALL,
}

# collective -> algorithms accepting the ``chunks`` pipelining knob. The
# selection subsystem plans chunk counts only for these; the runtime
# normalizes their default (chunks=1) into cache keys so auto and explicit
# callers share compiled executables.
CHUNKED = {
    "allgather": frozenset({"ring_pipeline"}),
    "scatter": frozenset({"pip_mcoll"}),
    "broadcast": frozenset({"pip_mcoll"}),
    "allreduce": frozenset({"pip_pipeline"}),
    "reduce_scatter": frozenset(),
    "alltoall": frozenset({"pip_pipeline"}),
}


def supports_chunks(collective: str, algo: str) -> bool:
    """True when ``algo`` accepts the ``chunks`` pipelining knob."""
    return algo in CHUNKED.get(collective, ())


# collective -> algorithms accepting the ``codec`` compression knob (the
# collectives where compressed execution is semantically sound: reductions
# decode before summing; gathers/exchanges decode at the receiver). The
# selection subsystem plans codecs only for these under the caller's
# error budget; the runtime normalizes codec="none" into cache keys.
COMPRESSED = {
    "allgather": frozenset({"pip_mcoll"}),
    "scatter": frozenset({"pip_mcoll"}),
    "broadcast": frozenset({"pip_mcoll"}),
    "allreduce": frozenset({"pip_mcoll", "pip_pipeline"}),
    "reduce_scatter": frozenset({"pip_mcoll"}),
    "alltoall": frozenset({"pip_mcoll", "pip_pipeline"}),
}


def supports_codec(collective: str, algo: str) -> bool:
    """True when ``algo`` accepts the ``codec`` compression knob."""
    return algo in COMPRESSED.get(collective, ())


def algorithms(collective: str):
    return sorted(_REGISTRY[collective].keys())


def algorithm(collective: str, algo: str):
    """The raw per-device algorithm function (runs inside shard_map)."""
    return _REGISTRY[collective][algo]

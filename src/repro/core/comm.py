"""Communicator: the object API for collectives — blocking methods plus
persistent, nonblocking ops.

PiP-MColl's multi-object design wins by letting several communication
objects make progress concurrently instead of serializing on one blocking
call; MPI evolved the same way with persistent collectives
(``MPI_Allreduce_init`` / ``MPI_Start`` / ``MPI_Wait`` in MPI Advance) and
with binding collectives to a long-lived communicator object instead of
re-deriving topology per call. This module is that shape on JAX:

  * :class:`Communicator` owns ``(mesh, topo, selector)`` and fronts the
    runtime's build/exec caches (``repro.core.runtime`` is the cache
    backend). One method per collective — ``comm.allreduce(x, algo="auto",
    chunks=..., codec=..., error_budget=...)`` — replaces the old
    stringly-typed free function; kwargs are validated when the plan is
    constructed, not mid-trace.
  * ``comm.split(axes=...)`` makes **groups first-class** (the
    ``MPI_Comm_split`` analog): it returns a child Communicator scoped to
    a sub-topology over the named mesh axes — its collectives run
    independently per group (SPMD: one child object serves every group
    along the orthogonal axes), its tuning table rows are namespaced by
    the group tag, and its plan/exec/persistent caches key on the group
    topology so siblings of identical shape share compiled entries.
    ``split(color=..., key=...)`` handles irregular groups by building a
    sub-mesh per color.
  * :class:`PlanSpec` normalizes the plan knobs exactly once (``chunks=None``
    == ``chunks=1`` == omitted; ``codec=None`` == ``codec="none"`` ==
    omitted; ``chunk_bytes`` folds into ``chunks``), so every call path of
    one plan shares a single exec-cache entry.
  * ``op = comm.allreduce_init(...)`` returns a :class:`PersistentOp`:
    the ``(algo, chunks, codec)`` plan is resolved and the executable
    AOT-compiled exactly once at init; every ``op.start(x)`` reuses it and
    returns a :class:`CollHandle` immediately (JAX async dispatch), so
    ``handle.wait()`` composes into software pipelining — start bucket i's
    allreduce, do other work, then wait. ``depth`` bounds outstanding
    starts (``depth>=2`` = double buffering); ``donate=True`` donates the
    operand buffer on backends that support aliasing.

:func:`communicator` (the per-(mesh, topo) memo below) is the canonical
entry point for hot loops that cannot keep a handle around.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune, runtime
from repro.core import telemetry as _tm
from repro.core.topology import Topology


def _default_topo(mesh) -> Optional[Topology]:
    """``Topology.from_mesh`` when the mesh carries the default node/local
    axes; ``None`` (an unscoped root) otherwise."""
    try:
        return Topology.from_mesh(mesh)
    except (KeyError, ValueError):
        return None


# ---------------------------------------------------------------------------
# plan spec: one normalization point for every call path
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """The caller's plan request for one collective invocation, validated
    and normalized at construction.

    Normalization rules (the single place they live):
      * ``chunks=None`` means "unpinned" and is dropped — the resolver
        fills the default (1) or the selector's chunk count, so ``None``,
        ``1`` and "omitted" share one exec-cache entry;
      * ``codec=None`` likewise drops (resolver default ``"none"``);
      * ``chunk_bytes`` is size-relative sugar the resolver converts to a
        concrete ``chunks`` against the operand;
      * ``error_budget`` must be a non-negative float here — schedule
        callables live one level up (the persistent gradient-sync op).
    """

    collective: str
    algo: str = "auto"
    chunks: Optional[int] = None
    chunk_bytes: Optional[int] = None
    codec: Optional[str] = None
    error_budget: float = 0.0
    stacked: bool = True
    #: carry-threaded persistent program: start(x, carry=state) ->
    #: wait() -> (result, new_state). Only meaningful for persistent ops
    #: on carry-capable algorithms (error-feedback allreduce).
    carry: bool = False

    def __post_init__(self):
        if self.collective not in runtime.collectives():
            raise ValueError(f"unknown collective {self.collective!r}; "
                             f"one of {runtime.collectives()}")
        if self.carry and self.collective != "allreduce":
            raise ValueError(
                f"carry state threading is only supported on allreduce "
                f"(error-feedback reductions), not {self.collective!r}")
        if self.chunks is not None and int(self.chunks) < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.chunk_bytes is not None and int(self.chunk_bytes) < 1:
            raise ValueError(
                f"chunk_bytes must be >= 1, got {self.chunk_bytes}")
        if callable(self.error_budget):
            raise TypeError(
                "error_budget schedules (callables) are only accepted by "
                "the persistent gradient-sync op "
                "(train.manual_step.make_overlapped_train_step); "
                "per-call plans need a float")
        if float(self.error_budget) < 0.0:
            raise ValueError(
                f"error_budget must be >= 0, got {self.error_budget}")

    def kwargs(self) -> Dict[str, Any]:
        """The normalized knob dict handed to the resolver (``None`` knobs
        dropped so unpinned and default-pinned calls share cache keys)."""
        kw: Dict[str, Any] = {}
        if self.chunks is not None:
            kw["chunks"] = int(self.chunks)
        if self.chunk_bytes is not None:
            kw["chunk_bytes"] = int(self.chunk_bytes)
        if self.codec is not None:
            kw["codec"] = str(self.codec)
        return kw


class _Proto:
    """Shape/dtype stand-in for plan resolution without a live array."""

    __slots__ = ("shape", "dtype")

    def __init__(self, shape, dtype):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = jnp.dtype(dtype)

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * self.dtype.itemsize


# ---------------------------------------------------------------------------
# persistent nonblocking ops
# ---------------------------------------------------------------------------


class CollHandle:
    """One in-flight persistent-op invocation. ``wait()`` yields the result
    exactly once; a second ``wait`` is a misuse error (like MPI requests,
    which are invalidated by completion)."""

    __slots__ = ("_op", "_value", "_done", "_token", "_t0")

    def __init__(self, op: "PersistentOp", value, token=None, t0=0.0):
        self._op = op
        self._value = value
        self._done = False
        self._token = token
        self._t0 = t0

    @property
    def done(self) -> bool:
        """True once this handle has been waited on."""
        return self._done

    def wait(self, block: bool = True):
        """Complete the operation and return its result.

        ``block=True`` (default, MPI_Wait semantics) blocks until the
        result is materialized; ``block=False`` returns the async-dispatch
        future immediately — downstream JAX ops compose with it either
        way, so software pipelining just interleaves ``start``/``wait``.
        """
        if self._done:
            raise RuntimeError(
                f"double wait on a {self._op.collective} handle: each "
                f"start(x) yields one result")
        self._done = True
        self._op._inflight -= 1
        if block:
            jax.block_until_ready(self._value)
        if self._token is not None:
            # the telemetry window opened at start(): close it here, and a
            # blocking wait is a synced wall-clock sample for the drift
            # detector (the result is materialized — no extra device sync)
            _tm.end(self._token)
            if block:
                op = self._op
                _tm.observe_plan(op.comm.topo, op.collective,
                                 str(op.dtype), op._msg_nbytes, op.plan,
                                 _time.perf_counter() - self._t0,
                                 synced=True)
        return self._value


#: count of live (initialised, not yet released) persistent ops — the
#: rebind-hygiene observable: re-resolving a plan must release the old op,
#: so repeated plan crossings keep this flat instead of growing it
_LIVE_OPS = 0

#: monotone op id feeding per-op telemetry track names
_OP_SEQ = 0


def live_persistent_ops() -> int:
    """Number of :class:`PersistentOp` objects initialised and not yet
    :meth:`~PersistentOp.release`\\ d (process-wide)."""
    return _LIVE_OPS


class PersistentOp:
    """A persistent collective: plan resolved and executable compiled once
    at init (``comm.<collective>_init``), reused by every ``start``.

    ``start(x) -> CollHandle`` dispatches asynchronously and returns
    immediately; ``handle.wait() -> result`` completes it. At most
    ``depth`` starts may be outstanding (un-waited) at once — ``depth=1``
    is strict request/complete pairing, ``depth>=2`` enables double
    buffering (start bucket i+1 before waiting bucket i).

    ``carry=True`` builds the carry-threaded variant: ``start(x,
    carry=state)`` takes a second operand with the payload's spec and
    ``handle.wait()`` returns ``(result, new_state)`` — per-bucket
    error-feedback residuals riding the persistent compressed allreduce.

    Owners that re-resolve plans must :meth:`release` the op they replace
    (``MPI_Request_free`` analog): release drops the compiled-callable
    reference (the donated-buffer pin with ``donate=True``) and makes any
    later ``start`` a clear error. The compiled executable itself stays in
    the runtime's LRU exec cache, so releasing and re-initialising an
    identical spec never recompiles.
    """

    def __init__(self, comm: "Communicator", collective: str,
                 shape: Tuple[int, ...], dtype, algo: str,
                 kw: Dict[str, Any], *, stacked: bool = True,
                 depth: int = 1, donate: bool = False,
                 carry: bool = False):
        if int(depth) < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.comm = comm
        self.collective = collective
        self.shape = tuple(int(s) for s in shape)
        self.dtype = jnp.dtype(dtype)
        self.algo = algo
        self.kw = dict(kw)
        self.stacked = bool(stacked)
        self.depth = int(depth)
        self.donate = bool(donate)
        self.carry = bool(carry)
        self.starts = 0
        self._inflight = 0
        self._released = False
        total = int(math.prod(self.shape)) * self.dtype.itemsize
        # per-process message bytes in the cost model's convention
        # (mirrors runtime._message_bytes) — the drift detector's size key
        self._msg_nbytes = (max(1, total) if collective == "broadcast"
                            else max(1, total // comm.topo.world))
        global _LIVE_OPS, _OP_SEQ
        _OP_SEQ += 1
        # each op gets its own trace track, so concurrent in-flight windows
        # (per-bucket overlap) render as parallel lanes, never stacked
        self._track = f"comm:{collective}#{_OP_SEQ}"
        t0 = _time.perf_counter() if _tm.enabled() else 0.0
        self._compiled, self._in_sharding = runtime.compile_persistent(
            comm.mesh, comm.topo, collective, algo, self.shape, self.dtype,
            stacked=stacked, donate=donate, carry=self.carry, **self.kw)
        if _tm.enabled():
            _tm.emit(f"persistent_init/{collective}", t0,
                     _time.perf_counter() - t0, cat="persistent",
                     **self._tags())
        _tm.counter("comm.persistent_inits").inc()
        _LIVE_OPS += 1

    def _tags(self) -> Dict[str, Any]:
        return _tm.plan_tags(self.collective, self.algo, self.chunks,
                             self.codec, self.comm.topo.group or "",
                             nbytes=self._msg_nbytes)

    @property
    def chunks(self) -> int:
        return int(self.kw.get("chunks", 1))

    @property
    def codec(self) -> str:
        return str(self.kw.get("codec", "none"))

    @property
    def plan(self) -> str:
        """The resolved plan key (``algo#cN@codec``, defaults omitted)."""
        return autotune.encode_plan(self.algo, self.chunks, self.codec)

    @property
    def inflight(self) -> int:
        return self._inflight

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        """Free this op (``MPI_Request_free``): drop the compiled-callable
        reference and retire it from the live-op count. Idempotent; any
        ``start`` after release raises. The compiled executable stays in
        the runtime exec cache (re-init of the same spec is a cache hit)."""
        global _LIVE_OPS
        if self._released:
            return
        self._released = True
        self._compiled = None
        _LIVE_OPS -= 1
        _tm.counter("comm.persistent_releases").inc()
        if _tm.enabled():
            _tm.instant(f"persistent_release/{self.collective}",
                        cat="persistent", starts=self.starts,
                        **self._tags())

    def _check_operand(self, x, what: str = "operand"):
        if not isinstance(x, jax.Array):
            x = jnp.asarray(x)
        if tuple(x.shape) != self.shape or x.dtype != self.dtype:
            raise ValueError(
                f"persistent {self.collective} op compiled for "
                f"{self.shape}/{self.dtype}, got {what} {tuple(x.shape)}/"
                f"{x.dtype}; init a new op for a new operand spec")
        if getattr(x, "sharding", None) != self._in_sharding:
            x = runtime.to_sharding(x, self._in_sharding)
        return x

    def start(self, x, carry=None) -> CollHandle:
        """Dispatch one invocation of the compiled plan on ``x`` and return
        its handle immediately (no recompile, no cache lookup). A carry op
        additionally takes ``carry=state`` (same spec as ``x``) and its
        handle's ``wait()`` returns ``(result, new_state)``."""
        if self._released:
            raise RuntimeError(
                f"start() on a released {self.collective} persistent op; "
                f"init a new op (release() retired this one)")
        if self._inflight >= self.depth:
            raise RuntimeError(
                f"{self.collective} persistent op already has "
                f"{self._inflight} outstanding start(s) at depth="
                f"{self.depth}; wait() the previous handle first, or init "
                f"with depth>=2 for double buffering")
        if self.carry != (carry is not None):
            raise ValueError(
                f"{self.collective} persistent op was compiled with "
                f"carry={self.carry}; start() "
                + ("requires carry=state" if self.carry
                   else "does not take a carry operand"))
        x = self._check_operand(x)
        self._inflight += 1
        self.starts += 1
        token, t0 = None, 0.0
        if _tm.enabled():
            # the start->wait window rides this op's own track, so nested /
            # concurrent bucket windows stay visible next to compute spans
            t0 = _time.perf_counter()
            token = _tm.begin(f"{self.collective}[{self.plan}]",
                              cat="comm", track=self._track, **self._tags())
        if self.carry:
            carry = self._check_operand(carry, what="carry")
            return CollHandle(self, self._compiled(x, carry), token, t0)
        return CollHandle(self, self._compiled(x), token, t0)

    def __call__(self, x, carry=None):
        """Blocking convenience: ``start(x).wait()``."""
        return self.start(x, carry=carry).wait()


# ---------------------------------------------------------------------------
# the communicator
# ---------------------------------------------------------------------------


class Communicator:
    """A long-lived collective context bound to ``(mesh, topo)``.

    Owns the selector handle and fronts the runtime's build/exec caches;
    exposes one blocking method per collective plus ``*_init`` constructors
    for persistent nonblocking ops, and :meth:`split` for sub-communicators
    over a subset of the mesh. Construct once per (mesh, topology) and
    reuse — or use :func:`communicator` for the process-wide memo.

    A Communicator built on a mesh whose axes don't map onto the default
    node/local topology (e.g. a 3-axis MoE mesh) is an **unscoped root**:
    ``split(axes=...)`` works, collective methods raise until scoped.
    """

    def __init__(self, mesh, topo: Optional[Topology] = None, *,
                 selector: Optional[autotune.Selector] = None):
        self.mesh = mesh
        if topo is None:
            topo = _default_topo(mesh)
        self.topo = topo
        self.selector = (selector if selector is not None
                         else autotune.default_selector())
        self._groups: Dict[tuple, "Communicator"] = {}

    def __repr__(self) -> str:
        if self.topo is None:
            return (f"Communicator(unscoped root, "
                    f"mesh axes={tuple(self.mesh.axis_names)})")
        grp = f", group={self.topo.group!r}" if self.topo.group else ""
        return (f"Communicator({self.topo.n_nodes}x{self.topo.n_local}, "
                f"axes={self.topo.axes}{grp})")

    def _require_topo(self) -> Topology:
        if self.topo is None:
            raise ValueError(
                "this Communicator is an unscoped root — mesh axes "
                f"{tuple(self.mesh.axis_names)} do not map onto the default "
                "node/local topology; call split(axes=...) to scope it to a "
                "group before running collectives")
        return self.topo

    # -- sub-communicators --------------------------------------------------

    def split(self, axes=None, *, color=None, key=None,
              group: Optional[str] = None):
        """The ``MPI_Comm_split`` analog: derive child communicator(s)
        scoped to a subset of this communicator's processes.

        Two forms:

        ``split(axes=...)`` — regular (mesh-aligned) groups. ``axes`` is
        one mesh axis name or a ``(node_axis, local_axis)`` pair; the child
        shares this mesh and runs every group along the orthogonal axes in
        one SPMD program, so a single child object serves all siblings.
        Its :class:`~repro.core.topology.Topology` is derived with
        :meth:`Topology.subset` (link classes inherited from the parent
        where the axis matches), its tuning-table rows carry the group tag
        (``group=`` overrides the default ``"x".join(axes)``), and because
        children are memoized here, repeated splits of the same spec share
        plan/exec/persistent caches.

        ``split(color=..., key=...)`` — irregular groups. ``color`` is a
        sequence of ``world`` ints (one per parent rank, parent flat device
        order); ranks with equal color form a group, ordered by
        ``(key[rank], rank)`` (``key`` defaults to parent rank). Returns
        ``{color: Communicator}``, each on its own ``(1, group_size)``
        sub-mesh. Use this for groups that don't align with mesh axes.

        Splitting a child again (split-of-split) composes naturally.
        """
        if (axes is None) == (color is None):
            raise ValueError("split() takes exactly one of axes= or color=")
        if axes is not None:
            if key is not None:
                raise ValueError("key= only applies to color splits")
            ax = (axes,) if isinstance(axes, str) else tuple(axes)
            gk = ("axes", ax, group)
            hit = self._groups.get(gk)
            if hit is None:
                topo = Topology.subset(self.mesh, ax, parent=self.topo,
                                       group=group)
                hit = self._groups[gk] = Communicator(
                    self.mesh, topo, selector=self.selector)
            return hit
        return self._split_color(color, key, group)

    def _split_color(self, color, key, group: Optional[str]
                     ) -> Dict[Any, "Communicator"]:
        devices = list(np.asarray(self.mesh.devices).flat)
        world = len(devices)
        color = tuple(int(c) for c in color)
        if len(color) != world:
            raise ValueError(
                f"color needs one entry per parent rank: got {len(color)} "
                f"for world {world}")
        key = (tuple(range(world)) if key is None
               else tuple(int(k) for k in key))
        if len(key) != world:
            raise ValueError(
                f"key needs one entry per parent rank: got {len(key)} "
                f"for world {world}")
        gk = ("color", color, key, group)
        hit = self._groups.get(gk)
        if hit is None:
            hit = {}
            for c in sorted(set(color)):
                ranks = sorted((r for r in range(world) if color[r] == c),
                               key=lambda r: (key[r], r))
                sub = jax.sharding.Mesh(
                    np.asarray([devices[r] for r in ranks]).reshape(
                        1, len(ranks)),
                    ("node", "local"))
                tag = group if group is not None else f"color{c}"
                topo = dataclasses.replace(Topology.from_mesh(sub),
                                           group=tag)
                hit[c] = Communicator(sub, topo, selector=self.selector)
            self._groups[gk] = hit
        return dict(hit)

    # -- plan resolution ----------------------------------------------------

    def plan(self, collective: str, nbytes: int, dtype: str = "float32",
             error_budget: float = 0.0) -> autotune.Selection:
        """The selector's ``(algo, chunks, codec)`` plan for one payload
        size on this communicator's topology (consumers that execute inside
        their own shard_map bodies — MoE dispatch/combine, the fused train
        step — resolve here and run the mcoll algorithm themselves)."""
        return self.selector.choose(collective, self._require_topo(),
                                    int(nbytes), dtype=dtype,
                                    error_budget=float(error_budget))

    def _resolve(self, spec: PlanSpec, proto, extra: Dict[str, Any]
                 ) -> Tuple[str, Dict[str, Any]]:
        kw = spec.kwargs()
        overlap = set(kw) & set(extra)
        if overlap:
            raise ValueError(f"duplicate plan knobs {sorted(overlap)}")
        kw.update(extra)
        topo = self._require_topo()
        t0 = _time.perf_counter() if _tm.enabled() else 0.0
        algo_r, kw_r = runtime.resolve_algo(topo, spec.collective,
                                            spec.algo, proto, kw,
                                            error_budget=spec.error_budget,
                                            selector=self.selector)
        if _tm.enabled():
            _tm.emit(f"plan_resolve/{spec.collective}", t0,
                     _time.perf_counter() - t0, cat="resolve",
                     requested=spec.algo,
                     **_tm.plan_tags(spec.collective, algo_r,
                                     int(kw_r.get("chunks", 1)),
                                     str(kw_r.get("codec", "none")),
                                     topo.group or "",
                                     nbytes=runtime._message_bytes(
                                         spec.collective, topo, proto)))
        return algo_r, kw_r

    # -- blocking methods ---------------------------------------------------

    def _call(self, name: str, x, *, algo: str = "auto",
              chunks: Optional[int] = None,
              chunk_bytes: Optional[int] = None,
              codec: Optional[str] = None, error_budget: float = 0.0,
              stacked: bool = True, **kw):
        spec = PlanSpec(name, algo, chunks, chunk_bytes, codec,
                        error_budget, stacked)
        x = runtime.global_operand(self.mesh, name, x)
        algo_r, kw_r = self._resolve(spec, x, kw)
        return runtime.run_resolved(self.mesh, self._require_topo(), name,
                                    algo_r, x, stacked=stacked, **kw_r)

    def allreduce(self, x, **knobs):
        """Sum-allreduce: in ``(world, m, ...)`` sharded dim0, out the
        reduced payload stacked per device. Knobs: ``algo`` (default
        "auto"), ``chunks``/``chunk_bytes``, ``codec``, ``error_budget``,
        plus algorithm-specific kwargs (``radix``, ``inter``, ...)."""
        return self._call("allreduce", x, **knobs)

    def reduce_scatter(self, x, **knobs):
        """Reduce-scatter: in ``(world, world*s, ...)`` sharded dim0, out
        each device's reduced shard (global ``(world*s, ...)``)."""
        return self._call("reduce_scatter", x, **knobs)

    def allgather(self, x, *, stacked: bool = True, **knobs):
        """Allgather: in ``(world*m, ...)`` sharded dim0; out stacked
        ``(world, world*m, ...)`` (row d = device d's full copy) or the
        replicated gather with ``stacked=False``."""
        return self._call("allgather", x, stacked=stacked, **knobs)

    def alltoall(self, x, **knobs):
        """All-to-all: in ``(world, world, s...)`` sharded dim0, out the
        transposed exchange."""
        return self._call("alltoall", x, **knobs)

    def broadcast(self, x, **knobs):
        """Broadcast from ``root`` (default 0): in ``(m, ...)`` replicated,
        out stacked ``(world, m, ...)``."""
        return self._call("broadcast", x, **knobs)

    def scatter(self, x, **knobs):
        """Scatter from ``root`` (default 0): in ``(world*m, ...)``
        replicated, out each device's shard."""
        return self._call("scatter", x, **knobs)

    def invoke(self, name: str, x, **knobs):
        """Name-indexed dispatch to the blocking methods (parametrized
        sweeps); new call sites should prefer the per-collective
        methods."""
        method = getattr(self, name, None)
        if name not in runtime.collectives() or method is None:
            raise ValueError(f"unknown collective {name!r}; "
                             f"one of {runtime.collectives()}")
        return method(x, **knobs)

    # -- persistent nonblocking ops -----------------------------------------

    def persistent(self, name: str, x=None, *, shape=None, dtype=None,
                   algo: str = "auto", chunks: Optional[int] = None,
                   chunk_bytes: Optional[int] = None,
                   codec: Optional[str] = None, error_budget: float = 0.0,
                   stacked: bool = True, depth: int = 1,
                   donate: bool = False, carry: bool = False,
                   **kw) -> PersistentOp:
        """Init a :class:`PersistentOp` for ``name`` on a fixed operand
        spec — pass an example operand ``x`` (array or ShapeDtypeStruct) or
        explicit ``shape=``/``dtype=``. The ``(algo, chunks, codec)`` plan
        is resolved and the executable compiled here, once.

        ``carry=True`` (allreduce only) threads a per-op state operand:
        ``op.start(x, carry=state)``; ``handle.wait()`` returns
        ``(result, new_state)`` — the error-feedback hookup for
        compressed gradient sync. The resolved algorithm must accept an
        ``err`` state (the pip family does; ``xla``/``flat_rd`` do not)."""
        if x is not None:
            shape = tuple(x.shape)
            dtype = x.dtype
        if shape is None or dtype is None:
            raise ValueError("persistent op needs an example operand x or "
                             "explicit shape= and dtype=")
        spec = PlanSpec(name, algo, chunks, chunk_bytes, codec,
                        error_budget, stacked, carry)
        proto = _Proto(shape, dtype)
        algo_r, kw_r = self._resolve(spec, proto, kw)
        return PersistentOp(self, name, proto.shape, proto.dtype, algo_r,
                            kw_r, stacked=stacked, depth=depth,
                            donate=donate, carry=carry)

    def allreduce_init(self, x=None, **knobs) -> PersistentOp:
        return self.persistent("allreduce", x, **knobs)

    def reduce_scatter_init(self, x=None, **knobs) -> PersistentOp:
        return self.persistent("reduce_scatter", x, **knobs)

    def allgather_init(self, x=None, **knobs) -> PersistentOp:
        return self.persistent("allgather", x, **knobs)

    def alltoall_init(self, x=None, **knobs) -> PersistentOp:
        return self.persistent("alltoall", x, **knobs)

    def broadcast_init(self, x=None, **knobs) -> PersistentOp:
        return self.persistent("broadcast", x, **knobs)

    def scatter_init(self, x=None, **knobs) -> PersistentOp:
        return self.persistent("scatter", x, **knobs)

    def split_lattice(self) -> Tuple["Communicator", ...]:
        """Every mesh-aligned split child of this communicator: one per
        single active (size > 1) axis, plus the full multi-axis group when
        more than one axis is active — e.g. a 2x4 mesh yields the
        ``("node",)``, ``("local",)`` and ``("node", "local")`` children.
        Children are the same memoized objects :meth:`split` returns."""
        topo = self._require_topo()
        axes = tuple(topo.active_axes)
        combos = [(a,) for a in axes]
        if len(axes) > 1:
            combos.append(tuple(axes))
        return tuple(self.split(axes=c) for c in combos)

    # -- calibration / observability passthroughs ---------------------------

    def calibrate(self, include_splits: bool = False, **kw):
        """Timed plan sweeps into this communicator's selector table
        (see ``runtime.calibrate``).

        ``include_splits=True`` additionally walks :meth:`split_lattice`
        and calibrates every mesh-aligned split child, so each group
        topology lands measured ``/g:``-keyed tuning rows *before* first
        use — a fresh ``comm.split(axes=...)`` then resolves
        ``algo="auto"`` from measurement instead of the cost-model prior.
        All rows land in the shared selector table; ``path=`` (when given)
        is saved once, after the whole lattice.

        Under a multi-controller runtime every process runs the same sweeps
        (SPMD — the timed programs are cross-process collectives), then the
        per-process tables are folded into rank 0's
        (``distributed.backend.merge_tuning_table``) so ``path=`` is
        written exactly once, by rank 0, with every rank's rows."""
        from repro.distributed import backend as _dist
        kw.setdefault("selector", self.selector)
        path = kw.pop("path", None)
        rows = list(runtime.calibrate(self.mesh, self._require_topo(), **kw))
        if include_splits:
            for child in self.split_lattice():
                rows.extend(runtime.calibrate(child.mesh, child.topo, **kw))
        if _dist.is_multiprocess():
            _dist.merge_tuning_table(self.selector.table)
        if path is not None and _dist.process_rank() == 0:
            self.selector.table.save(path)
        _dist.barrier("comm.calibrate/saved")
        return rows

    def cache_stats(self) -> "runtime.CacheStats":
        return runtime.cache_stats()

    def selection_stats(self) -> autotune.SelectionStats:
        return self.selector.stats


# ---------------------------------------------------------------------------
# process-wide memo
# ---------------------------------------------------------------------------


_COMMS: Dict[tuple, Communicator] = {}


def communicator(mesh, topo: Optional[Topology] = None) -> Communicator:
    """The memoized per-(mesh, topo) Communicator: repeated lookups from
    hot loops share one object per context instead of re-deriving it per
    call — and, because :meth:`Communicator.split` memoizes its children,
    per split spec too. On a mesh without the default node/local axes this
    returns the unscoped root (``split(axes=...)`` to scope it)."""
    t = topo if topo is not None else _default_topo(mesh)
    key = (mesh, t)
    hit = _COMMS.get(key)
    if hit is None:
        hit = _COMMS[key] = Communicator(mesh, t)
    return hit

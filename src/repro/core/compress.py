"""Error-bounded compression codecs for collective payloads.

The paper's companion work (C-Coll: "An Optimized Error-controlled MPI
Collective Framework Integrated with Lossy Compression", Huang et al. 2023)
shows the axis complementary to multi-object scheduling: integrate
error-bounded lossy compression *inside* the collective algorithms, so what
crosses the slow (inter-node) links shrinks by the codec's wire ratio while
the end-to-end error stays under a stated bound.

This module is the codec side of that subsystem:

  * a **registry** of codecs (:func:`codec`, :func:`codecs`,
    :func:`register`), each exposing ``encode``/``decode`` over slice
    batches, **error-feedback** helpers, and :class:`CodecMeta` —
    wire ratio, flop cost, and a *stated relative-error bound* the
    selection subsystem (``core.autotune``) checks against the caller's
    ``error_budget`` (``error_budget=0.0`` admits only lossless plans);
  * the **compressed execution** in ``core.mcoll`` encodes with these
    codecs before the slow ``node`` axis and decodes after;
  * the **cost model** (``core.costmodel.plan_cost``) prices a compressed
    plan as ``(C + B/ratio·β)·rounds + codec_flops``.

Codecs (stated elementwise round-trip bound, relative to ``max|slice|``):

  ===========  =========  ============  =====================================
  name         ratio      error bound   mechanism
  ===========  =========  ============  =====================================
  none         1.0x       0.0           identity (lossless)
  int8_block   ~3.9x      0.5/127       int8 blocks + per-256-block fp32 scale
  int4_block   ~7.8x      0.5/7         int4 nibble pairs packed two-per-byte
                                        + per-256-block fp32 scale
  fp8_sim      ~4.0x      2^-4          e4m3 cast against a per-slice scale
  topk         ~8.0x      1.0           keep the top 1/16 by magnitude
  zlib_sim     ~2x (meas) 0.0 (int)     bit-width packing: per-slice int32
                                        base + uint16 offsets (lossless for
                                        integer payloads whose per-slice
                                        range fits 16 bits — token ids,
                                        expert indices); wire bytes are
                                        *measured* by a byte-entropy /
                                        run-length stage, not assumed
  ===========  =========  ============  =====================================

Codecs whose :class:`CodecMeta` sets ``fused=True`` additionally register
Pallas lowerings in ``repro.kernels.codec`` that fuse encode+error-feedback
into one memory pass and decode+reduce into another;
:meth:`Codec.encode_with_feedback` / :meth:`Codec.encode_residual` /
:meth:`Codec.decode_reduce` route through them unless
:func:`jnp_reference_paths` disables fusion (the conformance A/B switch).
On non-TPU backends the kernels run in interpret mode, so CPU CI exercises
the same kernel bodies.

Encode operates on ``(S, L)`` float32 slice batches (``S`` slices headed for
``S`` wire peers) and returns a dict of arrays with leading dim ``S`` — the
wire form. Every leaf is a plain array, so ``lax.all_to_all`` /
``lax.all_gather`` over the wire axis apply leafwise (``jax.tree.map``).
``decode(comp, L)`` inverts to ``(S, L)`` float32.

The int8 tree-level helpers (:func:`quantize` / :func:`compress_tree` /
...) are the original ``optim.compress`` API, now owned here;
``repro.optim.compress`` re-exports them.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

#: quantization block length for the int8 block codec (elements per scale)
BLOCK = 256

#: density kept by the ``topk`` codec (fraction of elements per slice)
TOPK_DENSITY = 1.0 / 16.0

NONE = "none"


# ---------------------------------------------------------------------------
# codec metadata + base class
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CodecMeta:
    """Selection-facing metadata for one codec.

    wire_ratio:     fp32 payload bytes / wire bytes (>1 = compression); the
                    cost model divides the wire beta by this.
    flops_per_elem: modeled encode+decode work per element (elementwise
                    passes; priced against ``NetParams.flop_rate``).
    error_bound:    stated elementwise round-trip bound
                    ``max|decode(encode(x)) - x| <= error_bound * max|x|``
                    per slice. 0.0 means lossless. The selector admits a
                    codec only when ``error_bound <= error_budget``.
    integer_only:   the codec's domain is integer payloads (its wire form
                    exploits integer structure and its lossless claim holds
                    only there). Integer-only codecs are never admitted for
                    float payloads or reducing collectives — see
                    :func:`admissible`.
    fused:          the codec registers Pallas fused lowerings
                    (encode+error-feedback and decode+reduce in one memory
                    pass each) in ``repro.kernels.codec``; the hot-path
                    methods route through them while :func:`fused_enabled`.
    fused_flops_per_elem: modeled per-element work of the *fused* path —
                    fewer memory passes than ``flops_per_elem`` prices
                    (the codec cost is ~HBM-bound streaming, so fewer
                    passes is directly fewer modeled "flops"). ``None``
                    falls back to ``flops_per_elem``. The cost model reads
                    :func:`effective_flops_per_elem`, so autotuned
                    crossovers shift when fusion is on.
    """

    name: str
    wire_ratio: float
    flops_per_elem: float
    error_bound: float
    integer_only: bool = False
    fused: bool = False
    fused_flops_per_elem: Optional[float] = None

    @property
    def lossless(self) -> bool:
        return self.error_bound == 0.0


# ---------------------------------------------------------------------------
# fused-lowering toggle (the conformance A/B switch)
# ---------------------------------------------------------------------------

_FUSED_ENABLED = True


def fused_enabled() -> bool:
    """Whether fused Pallas lowerings are routed (module-level switch)."""
    return _FUSED_ENABLED


def set_fused(enabled: bool) -> bool:
    """Set the fused-lowering switch; returns the previous value."""
    global _FUSED_ENABLED
    prev = _FUSED_ENABLED
    _FUSED_ENABLED = bool(enabled)
    return prev


@contextlib.contextmanager
def jnp_reference_paths():
    """Context manager forcing the pure-jnp reference paths (fusion off).

    The conformance suite runs every fused codec A/B under this to assert
    the kernel paths match the jnp paths; the runtime's plan caches key on
    :func:`fused_enabled` so the two variants compile separately."""
    prev = set_fused(False)
    try:
        yield
    finally:
        set_fused(prev)


class Codec:
    """Base codec: subclasses set ``meta`` and implement encode/decode.

    ``encode(x2d)``: ``(S, L)`` float32 -> dict of arrays, leading dim S.
    ``decode(comp, length)``: inverse, -> ``(S, length)`` float32.
    """

    meta: CodecMeta

    def encode(self, x2d):
        raise NotImplementedError

    def decode(self, comp, length: int):
        raise NotImplementedError

    # -- fused lowerings ----------------------------------------------------

    def _lowering(self):
        """The registered fused Pallas lowering, or None (jnp path)."""
        if not (self.meta.fused and _FUSED_ENABLED):
            return None
        from repro.kernels import codec as _kernels  # lazy: no import cycle
        return _kernels.lowering(self.meta.name)

    # -- error feedback -----------------------------------------------------

    def encode_with_feedback(self, x2d, err):
        """Encode ``x2d + err``; return (wire form, new feedback state).

        Error feedback (Karimireddy et al. 2019): the round-trip residual is
        carried into the next call, so the *accumulated* signal tracks the
        true accumulated signal to within one step's residual — lossy
        gradient compression keeps converging.

        Fused codecs execute this as ONE memory pass (read payload +
        carried residual, emit wire form + new residual from registers);
        the jnp path below materializes the decode round trip.
        """
        lw = self._lowering()
        if lw is not None:
            return lw.encode_feedback(jnp.asarray(x2d).astype(jnp.float32),
                                      err)
        corrected = x2d.astype(jnp.float32) + err
        comp = self.encode(corrected)
        return comp, corrected - self.decode(comp, x2d.shape[-1])

    def encode_residual(self, x2d):
        """Encode ``x2d``; return (wire form, round-trip residual).

        The residual-producing encode on the compressed-collective hot path
        (``core.mcoll``): fused codecs emit wire blocks and the residual in
        one pass, never materializing ``decode(encode(x))``."""
        lw = self._lowering()
        if lw is not None:
            return lw.encode_residual(jnp.asarray(x2d).astype(jnp.float32))
        x2d = jnp.asarray(x2d).astype(jnp.float32)
        comp = self.encode(x2d)
        return comp, x2d - self.decode(comp, x2d.shape[-1])

    def decode_reduce(self, comp, length: int):
        """Decode the ``(W, ...)`` wire form and sum over the peer axis.

        Fused codecs accumulate the incoming wire slices into f32 registers
        directly (one pass over the wire bytes) instead of
        dequantize-then-``sum(axis=0)``."""
        lw = self._lowering()
        if lw is not None:
            return lw.decode_reduce(comp, length)
        return self.decode(comp, length).sum(axis=0)

    # -- observability ------------------------------------------------------

    def wire_bytes(self, comp) -> int:
        """Actual bytes of the wire form (sanity check vs meta.wire_ratio)."""
        return sum(int(a.size) * jnp.dtype(a.dtype).itemsize
                   for a in jax.tree.leaves(comp))

    def achieved_ratio(self, x2d) -> float:
        """Measured compression ratio on one payload: float32 payload bytes
        over actual wire bytes of ``encode(x2d)`` (>= 1 means the codec
        shrinks the wire). Runs an encode, so callers sample it — the
        telemetry EF probe and the benchmark compression section — rather
        than calling it per collective."""
        x2d = jnp.asarray(x2d, jnp.float32)
        return float(x2d.size * 4.0) / max(1, self.wire_bytes(
            self.encode(x2d)))


# ---------------------------------------------------------------------------
# int8 block codec (the original optim.compress math, generalized)
# ---------------------------------------------------------------------------


class Int8BlockCodec(Codec):
    """Per-block int8 quantization: 256-element blocks, one fp32 scale each.

    Round-to-nearest against ``blockmax/127`` bounds the elementwise error
    by ``0.5 * blockmax/127`` — stated bound 0.5/127 relative to the slice
    max (block max <= slice max). Wire: 1 byte/elem + 4 bytes per block
    = 3.94x vs fp32. All-zero blocks get scale 0 (the divisor is clamped,
    so q is exactly 0 — no NaNs)."""

    meta = CodecMeta("int8_block", wire_ratio=BLOCK * 4 / (BLOCK + 4.0),
                     flops_per_elem=3.0, error_bound=0.5 / 127.0,
                     fused=True, fused_flops_per_elem=1.5)

    def encode(self, x2d):
        S, L = x2d.shape
        nb = -(-L // BLOCK)
        padded = jnp.pad(x2d.astype(jnp.float32), ((0, 0), (0, nb * BLOCK - L)))
        blocks = padded.reshape(S, nb, BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=2) / 127.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12)),
                     -127, 127)
        return {"q": q.astype(jnp.int8), "scale": scale}

    def decode(self, comp, length: int):
        q, scale = comp["q"], comp["scale"]
        S = q.shape[0]
        deq = q.astype(jnp.float32) * scale[..., None]
        return deq.reshape(S, -1)[:, :length]


_INT8 = Int8BlockCodec()


def quantize(x):
    """x: float array -> (int8 blocks, fp32 per-block scales).

    Legacy flat-array face of :class:`Int8BlockCodec` (single
    implementation of the block math; this just adapts shapes)."""
    comp = _INT8.encode(jnp.asarray(x).reshape(1, -1))
    return comp["q"][0], comp["scale"][0]


def dequantize(q, scale, shape):
    n = 1
    for d in shape:
        n *= d
    return _INT8.decode({"q": q[None], "scale": scale[None]},
                        n)[0].reshape(shape)


# ---------------------------------------------------------------------------
# int4 block codec: nibble pairs packed two-per-byte
# ---------------------------------------------------------------------------


class Int4BlockCodec(Codec):
    """Per-block int4 quantization, packed two values per wire byte.

    Same block structure as :class:`Int8BlockCodec` but quantized to
    ``[-7, 7]`` against ``blockmax/7`` and shipped as nibble pairs: each
    wire byte holds two consecutive elements (+8 bias, even element in the
    low nibble) — 0.5 bytes/elem + 4 bytes per block, ~7.8x vs fp32.
    Round-to-nearest bounds the elementwise error by ``0.5 * blockmax/7``,
    so the stated bound is 0.5/7 relative to the slice max. The packing
    layout here is the contract the fused Pallas kernels
    (``kernels/codec.py``) reproduce bit-for-bit."""

    meta = CodecMeta("int4_block", wire_ratio=BLOCK * 4 / (BLOCK / 2 + 4.0),
                     flops_per_elem=4.0, error_bound=0.5 / 7.0,
                     fused=True, fused_flops_per_elem=2.0)

    def encode(self, x2d):
        S, L = x2d.shape
        nb = -(-L // BLOCK)
        padded = jnp.pad(x2d.astype(jnp.float32), ((0, 0), (0, nb * BLOCK - L)))
        blocks = padded.reshape(S, nb, BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=2) / 7.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12)),
                     -7, 7)
        pairs = (q.astype(jnp.int32) + 8).reshape(S, nb, BLOCK // 2, 2)
        packed = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)
        return {"q": packed, "scale": scale}

    def decode(self, comp, length: int):
        packed, scale = comp["q"], comp["scale"]
        S, nb = scale.shape
        b = packed.astype(jnp.int32)
        lo = (b & 0xF) - 8
        hi = (b >> 4) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(S, nb, BLOCK)
        deq = q.astype(jnp.float32) * scale[..., None]
        return deq.reshape(S, -1)[:, :length]


# ---------------------------------------------------------------------------
# fp8 (e4m3) cast codec
# ---------------------------------------------------------------------------

_FP8_MAX = 448.0  # e4m3 finite max
_HAVE_FP8 = hasattr(jnp, "float8_e4m3fn")


def _sim_e4m3(x):
    """Mantissa-rounding fallback when the float8 dtype is unavailable:
    3 mantissa bits via frexp/ldexp (matches e4m3 normals' 2^-4 bound)."""
    m, e = jnp.frexp(x)
    return jnp.ldexp(jnp.round(m * 16.0) / 16.0, e)


class Fp8SimCodec(Codec):
    """e4m3 cast against a per-slice scale (``amax/448``).

    Round-to-nearest on a 3-bit mantissa bounds the relative error of every
    normal by 2^-4; scaling to the slice max keeps the whole slice in the
    normal range, so the stated bound is 2^-4 relative to the slice max.
    The wire form carries the fp8 payload bitcast to uint8 (collectives
    move uint8 everywhere) plus one fp32 scale per slice: ~4x vs fp32.

    Without the float8 dtype the frexp/ldexp fallback simulates only the
    *accuracy* (fp32 stays on the wire), so the declared ratio drops to
    1.0 — the selector then never prices savings that don't exist.
    """

    meta = CodecMeta("fp8_sim",
                     wire_ratio=4.0 * (1.0 - 1e-3) if _HAVE_FP8 else 1.0,
                     flops_per_elem=2.0, error_bound=2.0 ** -4,
                     fused=_HAVE_FP8, fused_flops_per_elem=1.0)

    def encode(self, x2d):
        x2d = x2d.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x2d), axis=1)
        scale = jnp.maximum(amax / _FP8_MAX, 1e-30)
        q = jnp.clip(x2d / scale[:, None], -_FP8_MAX, _FP8_MAX)
        if _HAVE_FP8:
            wire = lax.bitcast_convert_type(q.astype(jnp.float8_e4m3fn),
                                            jnp.uint8)
        else:
            wire = _sim_e4m3(q)
        return {"q": wire, "scale": scale}

    def decode(self, comp, length: int):
        q, scale = comp["q"], comp["scale"]
        if _HAVE_FP8:
            q = lax.bitcast_convert_type(q, jnp.float8_e4m3fn)
        return q.astype(jnp.float32)[:, :length] * scale[:, None]


# ---------------------------------------------------------------------------
# top-k sparsification codec
# ---------------------------------------------------------------------------


class TopKCodec(Codec):
    """Keep the ``TOPK_DENSITY`` largest-magnitude elements per slice.

    Dropped elements carry their full value as error, and the largest
    dropped magnitude can approach the slice max — the honest stated bound
    is 1.0 (admitted only under a permissive error budget; error feedback
    is what makes repeated top-k converge in gradient paths). Wire: (value
    fp32 + index int32) per kept element = ``1/(2*density)`` vs fp32."""

    meta = CodecMeta("topk", wire_ratio=1.0 / (2.0 * TOPK_DENSITY),
                     flops_per_elem=6.0, error_bound=1.0)

    def encode(self, x2d):
        x2d = x2d.astype(jnp.float32)
        S, L = x2d.shape
        k = max(1, int(math.ceil(L * TOPK_DENSITY)))
        _, idx = lax.top_k(jnp.abs(x2d), k)
        vals = jnp.take_along_axis(x2d, idx, axis=1)
        return {"v": vals, "i": idx.astype(jnp.int32)}

    def decode(self, comp, length: int):
        vals, idx = comp["v"], comp["i"]
        S = vals.shape[0]
        out = jnp.zeros((S, length), jnp.float32)
        return out.at[jnp.arange(S)[:, None], idx].set(vals)


# ---------------------------------------------------------------------------
# identity codec (the lossless plan dimension)
# ---------------------------------------------------------------------------


class NoneCodec(Codec):
    """Identity: the ``codec`` plan dimension's lossless value."""

    meta = CodecMeta(NONE, wire_ratio=1.0, flops_per_elem=0.0,
                     error_bound=0.0)

    def encode(self, x2d):
        return {"x": x2d.astype(jnp.float32)}

    def decode(self, comp, length: int):
        return comp["x"][:, :length]


# ---------------------------------------------------------------------------
# lossless integer bit-width packing (zlib_sim)
# ---------------------------------------------------------------------------


def _entropy_wire_bytes(raw: np.ndarray) -> int:
    """Measured byte estimate for one packed byte stream.

    Two stages a byte-stream compressor actually has, each computed from
    the concrete bytes (nothing assumed): an order-0 entropy coder
    (``n * H / 8`` bytes from the byte histogram) and a run-length coder
    (2 bytes per run: value + length). The estimate is the better of the
    two, never exceeding the raw stream."""
    raw = np.ascontiguousarray(raw, dtype=np.uint8).reshape(-1)
    n = int(raw.size)
    if n == 0:
        return 0
    hist = np.bincount(raw, minlength=256).astype(np.float64)
    p = hist[hist > 0] / n
    entropy_bits = float(-(p * np.log2(p)).sum())
    entropy_bytes = int(math.ceil(n * entropy_bits / 8.0))
    runs = int(1 + np.count_nonzero(raw[1:] != raw[:-1]))
    rle_bytes = 2 * runs
    return max(1, min(n, entropy_bytes, rle_bytes))


class ZlibSimCodec(Codec):
    """Lossless bit-width packing for small-range integer payloads.

    What a byte-stream compressor (zlib) exploits in token/index traffic is
    mostly the narrow value range; this codec captures that win in a fixed
    wire shape JAX can trace: per slice, one int32 ``base`` (the slice min)
    plus 16-bit offsets ``lo = v - base``. Wire: 2 bytes/elem + 4 bytes per
    slice, ~2x vs the 4-byte integer payload.

    Domain contract (why ``integer_only``): the round trip is exact iff
    every slice's value range fits 16 bits (``max - min < 2**16``) — true
    for vocabulary token ids, expert/router indices, and lengths, which are
    exactly the payloads otherwise forced to ``codec="none"``. Shapes are
    static under jit, so the 16-bit width is a declared contract, not a
    measured one; out-of-range offsets wrap (detectably garbage, not
    silently close). Float payloads and reducing collectives (the wire form
    cannot be summed) are excluded by :func:`admissible`.

    Unlike the float codecs, encode keeps integer dtypes as-is (no f32
    cast) and decode returns int32 — the compressed execution casts back to
    the caller's integer dtype, so values above 2**24 survive the trip.

    The wire accounting is *measured*, not assumed: :meth:`wire_bytes`
    runs the packed offsets through :func:`_entropy_wire_bytes` (order-0
    byte entropy vs run-length, whichever is smaller), ``meta.wire_ratio``
    is seeded at registration from a canonical token-id sample through the
    same estimator, and :meth:`refresh_ratio` re-measures it against a
    caller's real payload so the cost model prices observed bytes.
    """

    meta = CodecMeta("zlib_sim", wire_ratio=2.0 * (1.0 - 1e-3),
                     flops_per_elem=2.0, error_bound=0.0, integer_only=True)

    def __init__(self):
        # Seed the declared ratio from a measured sample (quasi-uniform
        # vocabulary token ids — the canonical integer payload) instead of
        # the historical assumed 2x. numpy-only: runs at import time.
        ids = (np.arange(4096, dtype=np.int64) * 2654435761) % 50257
        self.meta = dataclasses.replace(
            type(self).meta,
            wire_ratio=self._measured_ratio_np(ids.astype(np.int32)
                                               .reshape(1, -1)))

    @staticmethod
    def _measured_ratio_np(v2d: np.ndarray) -> float:
        """payload bytes / measured wire bytes for an int32 sample."""
        base = v2d.min(axis=1, keepdims=True)
        lo = (v2d - base).astype(np.uint16)
        wire = _entropy_wire_bytes(lo.view(np.uint8)) + 4 * v2d.shape[0]
        return float(v2d.size * 4.0 / wire)

    def wire_bytes(self, comp) -> int:
        """Measured wire bytes: entropy/run-length estimate on the packed
        offsets plus the 4-byte per-slice bases (overrides the assumed
        leaf-nbytes accounting of the base class)."""
        lo = np.asarray(jax.device_get(comp["lo"])).astype(np.uint16)
        n_slices = int(comp["base"].size)
        return _entropy_wire_bytes(lo.view(np.uint8)) + 4 * n_slices

    def refresh_ratio(self, x2d) -> float:
        """Re-measure ``meta.wire_ratio`` against a concrete sample payload
        and install it on this (registered) instance; returns the ratio."""
        v = np.asarray(jax.device_get(jnp.asarray(x2d))).astype(np.int32)
        if v.ndim == 1:
            v = v.reshape(1, -1)
        ratio = self._measured_ratio_np(v)
        self.meta = dataclasses.replace(self.meta, wire_ratio=ratio)
        return ratio

    def encode(self, x2d):
        v = jnp.asarray(x2d).astype(jnp.int32)
        base = jnp.min(v, axis=1)
        lo = (v - base[:, None]).astype(jnp.uint16)
        return {"lo": lo, "base": base}

    def decode(self, comp, length: int):
        lo, base = comp["lo"], comp["base"]
        return (base[:, None] + lo.astype(jnp.int32))[:, :length]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Codec] = {}


def register(c: Codec) -> Codec:
    _REGISTRY[c.meta.name] = c
    return c


register(NoneCodec())
register(_INT8)
register(Int4BlockCodec())
register(Fp8SimCodec())
register(TopKCodec())
register(ZlibSimCodec())


def codecs() -> Tuple[str, ...]:
    """All registered codec names, ``"none"`` first, rest sorted."""
    rest = sorted(n for n in _REGISTRY if n != NONE)
    return (NONE, *rest)


def lossy() -> Tuple[str, ...]:
    """Registered lossy codec names (sorted)."""
    return tuple(n for n in codecs() if not _REGISTRY[n].meta.lossless)


def codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown codec {name!r}; one of {codecs()}") \
            from None


def meta(name: str) -> CodecMeta:
    return codec(name).meta


def fused_codecs() -> Tuple[str, ...]:
    """Registered codec names advertising fused Pallas lowerings."""
    return tuple(n for n in codecs() if _REGISTRY[n].meta.fused)


def effective_flops_per_elem(name: str) -> float:
    """The per-element codec work the cost model should price *right now*:
    the fused figure when the codec advertises a fused lowering and fusion
    is enabled (fewer memory passes), else the jnp figure."""
    m = meta(name)
    if m.fused and _FUSED_ENABLED and m.fused_flops_per_elem is not None:
        return m.fused_flops_per_elem
    return m.flops_per_elem


#: collectives that sum payloads in wire form mid-flight — integer-only
#: codecs can't ride them (their wire form is not additive)
REDUCING = frozenset({"allreduce", "reduce_scatter"})


def admissible(name: str, collective, error_budget: float,
               integer_payload: bool = False) -> bool:
    """Whether one codec may carry one payload under one error budget.

    Three gates compose the domain check:
      * the codec's stated bound must fit the budget;
      * an ``integer_only`` codec needs an integer payload and a
        non-reducing collective (``collective=None`` skips that last
        check for callers without a collective in hand);
      * a lossy codec never touches an integer payload (token ids and
        indices must survive bit-exact).
    """
    m = meta(name)
    if m.error_bound > float(error_budget):
        return False
    if m.integer_only:
        return bool(integer_payload) and (collective is None
                                          or collective not in REDUCING)
    return m.lossless or not integer_payload


def for_budget(error_budget: float, collective=None,
               integer_payload: bool = False) -> Tuple[str, ...]:
    """Codec names admissible under ``error_budget`` (see
    :func:`admissible` for the domain gates). ``error_budget=0.0`` with a
    float payload -> lossless non-integer codecs only (the selector can
    provably never emit a lossy plan); an integer payload additionally
    admits the integer-only lossless codecs on non-reducing collectives."""
    return tuple(n for n in codecs()
                 if admissible(n, collective, error_budget, integer_payload))


def collective_tolerance(name: str, collective: str, world: int,
                         max_abs: float) -> float:
    """Absolute error tolerance for one compressed collective result.

    Derived from the codec's stated elementwise bound ``eps`` and how the
    compressed execution (``core.mcoll``) accumulates it:

      * allgather / alltoall: one encode/decode round trip -> ``eps * A``;
      * broadcast / scatter: the root encodes once and the tree forwards
        the wire form verbatim -> one round trip, ``eps * A``;
      * reduce_scatter: one encode per sender, errors sum over the
        ``world`` contributions -> ``eps * world * A``;
      * allreduce: sender residuals sum over ``world`` contributions
        (values up to ``n_local * A`` after the intra reduce), plus one
        requantization of the reduced slice -> ``2 * eps * world * A``.

    ``A`` is the max-abs of the *input* payload. Lossless codecs return 0.
    """
    eps = meta(name).error_bound
    if eps == 0.0:
        return 0.0
    factor = {"allgather": 1.0, "alltoall": 1.0,
              "broadcast": 1.0, "scatter": 1.0,
              "reduce_scatter": float(world),
              "allreduce": 2.0 * float(world)}.get(collective)
    if factor is None:
        raise ValueError(f"no compressed execution for {collective!r}")
    return eps * factor * float(max_abs)


# ---------------------------------------------------------------------------
# int8 tree-level helpers (the original optim.compress API, now thin
# adapters over the registry — one error-feedback code path)
# ---------------------------------------------------------------------------


def init_error_state(grads):
    """Zero-initialized error-feedback state matching a gradient tree
    (the carried-residual input to :meth:`Codec.encode_with_feedback`)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, error_state):
    """Quantize every leaf after adding carried error feedback.

    Returns ((qs, scales) list-trees aligned with grads, new_error_state).
    Each leaf rides :meth:`Codec.encode_with_feedback` on the registered
    int8 codec — the same (fused, when enabled) code path the compressed
    collectives use, not a parallel reimplementation."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error_state)
    qs, scales, new_err = [], [], []
    for g, e in zip(leaves, err_leaves):
        comp, resid = _INT8.encode_with_feedback(
            jnp.asarray(g).reshape(1, -1), jnp.asarray(e).reshape(1, -1))
        qs.append(comp["q"][0])
        scales.append(comp["scale"][0])
        new_err.append(resid[0].reshape(g.shape))
    return (qs, scales, treedef), jax.tree.unflatten(treedef, new_err)


def decompress_tree(compressed, shapes_like):
    qs, scales, treedef = compressed
    shape_leaves = [l.shape for l in jax.tree.leaves(shapes_like)]
    out = [dequantize(q, s, shp)
           for q, s, shp in zip(qs, scales, shape_leaves)]
    return jax.tree.unflatten(treedef, out)


def wire_bytes(compressed) -> int:
    qs, scales, _ = compressed
    return sum(_INT8.wire_bytes({"q": q, "scale": s})
               for q, s in zip(qs, scales))

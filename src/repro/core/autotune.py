"""Algorithm selection subsystem: pick the best algorithm for a collective
given message size, dtype, and topology.

This is the TPU analogue of an MPI library's collective tuning tables, with
two evidence sources layered the way MPI Advance layers runtime-selectable
variants over defaults:

  1. **cost-model priors** — the alpha-beta model (``core.costmodel``),
     parameterised by the topology's per-axis link metadata
     (``costmodel.net_for(topo)``), covering every algorithm registered in
     ``core.mcoll`` for all six collectives;
  2. **measured calibration** — timed sweeps run through
     ``runtime.calibrate`` (which drives ``runtime.run``, the Communicator
     backend, so timings include the real dispatch path), persisted as JSON
     :class:`TuningTable` keyed on (topology, collective, dtype, size
     bucket). When a measurement exists for the exact key it wins over the
     prior.

A resolved plan has three dimensions: the **algorithm**, its **chunk
count** (pipelining, PR 3), and its **codec** (error-bounded compression,
``core.compress``). Plans serialize as ``algo#cN@codec`` tuning-table keys
(:func:`encode_plan` / :func:`decode_plan`; defaults omitted, so old tables
keep resolving). Codec plans are gated by the caller's ``error_budget``:
a codec is a candidate only when its stated relative-error bound fits the
budget, and ``error_budget=0.0`` admits lossless plans only — in both the
prior enumeration and the measured-table filter.

The module-level :func:`choose` / :func:`tuning_table` keep the original
API, now backed by a shared default :class:`Selector`. ``runtime`` resolves
``algo="auto"`` through the same default selector, so every consumer
(MoE dispatch, gradient sync, serving, benchmarks) shares one table and one
set of selection stats.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Iterable, Optional, Tuple, Union

from repro.core import compress as _codecs
from repro.core import costmodel
from repro.core import mcoll as _mcoll
from repro.core.costmodel import NetParams
from repro.core.topology import Topology

# ---------------------------------------------------------------------------
# candidate registry: every implemented algorithm, minus infeasible ones
# ---------------------------------------------------------------------------

# algo -> feasibility predicate on the topology
_CONSTRAINTS = {
    "recursive_doubling": lambda topo: (topo.world & (topo.world - 1)) == 0,
}


def candidates(collective: str, topo: Optional[Topology] = None
               ) -> Tuple[str, ...]:
    """Candidate algorithms for ``collective``: the full ``core.mcoll``
    registry (so selector coverage can never drift from what is
    implemented), filtered by feasibility on ``topo``."""
    algos = tuple(_mcoll.algorithms(collective))
    if topo is not None:
        algos = tuple(a for a in algos
                      if _CONSTRAINTS.get(a, lambda t: True)(topo))
    return algos


def size_bucket(nbytes: int) -> int:
    """Power-of-two ceiling bucket for a message size (1 byte minimum)."""
    return 1 << max(0, int(nbytes - 1).bit_length())


# ---------------------------------------------------------------------------
# plan keys: (algorithm, chunk count, codec) -> "algo#cN@codec"
# ---------------------------------------------------------------------------

#: separator between an algorithm name and its chunk count in tuning-table
#: keys ("pip_pipeline#c8"); bare names mean chunks=1, so tables recorded
#: before chunked pipelining landed keep resolving.
PLAN_SEP = "#c"

#: separator before the codec name ("pip_pipeline#c8@int8_block"); absent
#: means codec="none", so pre-compression tables keep resolving.
CODEC_SEP = "@"


def encode_plan(algo: str, chunks: int = 1, codec: str = "none") -> str:
    """Tuning-table key for an (algo, chunks, codec) plan. Defaults are
    omitted, so the key for a plain algorithm is its bare name."""
    key = algo if chunks <= 1 else f"{algo}{PLAN_SEP}{int(chunks)}"
    if codec and codec != _codecs.NONE:
        key = f"{key}{CODEC_SEP}{codec}"
    return key


def decode_plan(key: str) -> Tuple[str, int, str]:
    """Inverse of :func:`encode_plan` (bare algorithm names -> chunks=1,
    codec="none")."""
    base, csep, codec = key.partition(CODEC_SEP)
    algo, sep, c = base.partition(PLAN_SEP)
    return (algo, int(c) if sep else 1, codec if csep else _codecs.NONE)


def predicted_seconds(collective: str, plan_key: str, topo: Topology,
                      nbytes: int) -> Optional[float]:
    """Cost-model seconds for an encoded plan key on ``topo`` — the prior
    the telemetry drift detector reports observed medians against. Returns
    ``None`` for plans that are implemented but not modeled (or whose
    codec name is unknown to this build)."""
    algo, chunks, codec = decode_plan(plan_key)
    try:
        return costmodel.plan_seconds(collective, algo, topo, int(nbytes),
                                      chunks=chunks, codec=codec)
    except (ValueError, KeyError):
        return None


def chunk_candidates(collective: str, algo: str, topo: Topology, nbytes: int,
                     net: NetParams,
                     cap: int = costmodel.MAX_CHUNKS) -> Tuple[int, ...]:
    """Chunk counts worth evaluating for one pair at one message size:
    unchunked, the analytic optimum, and its halved/doubled neighbors
    (selection takes the modeled minimum; calibration measures each)."""
    if not _mcoll.supports_chunks(collective, algo):
        return (1,)
    c = costmodel.optimal_chunks(collective, algo, topo, nbytes, net, cap)
    return tuple(sorted({1, max(1, c // 2), c, min(cap, c * 2)}))


def _integer_dtype(dtype: str) -> bool:
    """True for integer/bool payload dtypes, which must never compress
    lossily (kept string-based: this module is jax-free)."""
    return "int" in dtype or "bool" in dtype


def codec_candidates(collective: str, algo: str,
                     error_budget: float = 0.0,
                     dtype: str = "float32") -> Tuple[str, ...]:
    """Codec names worth evaluating for one (collective, algo) under an
    error budget: always ``"none"`` first; other codecs only when the
    algorithm has a compressed execution AND the codec is admissible for
    the payload domain (``compress.admissible``: bound fits the budget,
    integer-only codecs need integer payloads on non-reducing collectives,
    lossy codecs never touch integer payloads). ``error_budget=0.0`` on a
    float payload therefore yields ``("none",)`` for every pair — the
    selector can never emit a lossy plan — while an integer payload still
    admits the lossless integer packers."""
    if not _mcoll.supports_codec(collective, algo):
        return (_codecs.NONE,)
    return _codecs.for_budget(error_budget, collective,
                              integer_payload=_integer_dtype(dtype))


def plans(collective: str, topo: Topology, nbytes: int,
          net: Optional[Union[str, NetParams]] = None,
          codecs: Optional[Tuple[str, ...]] = None,
          dtype: str = "float32") -> Tuple[Tuple[str, int, str], ...]:
    """(algo, chunks, codec) calibration candidates for one message size:
    every feasible algorithm with chunk-count variants for the pipelined
    ones, plus one codec variant per domain-admissible non-identity codec
    (at chunks=1) for the codec-capable algorithms — lossy codecs for
    float payloads, lossless integer packers for integer ones.
    Calibration measures each; the tuning table stores them under
    :func:`encode_plan` keys."""
    net_p = (costmodel.net_for(topo) if net is None
             else costmodel.resolve_net(net))
    integer = _integer_dtype(dtype)
    out = []
    for algo in candidates(collective, topo):
        for c in chunk_candidates(collective, algo, topo, nbytes, net_p):
            out.append((algo, c, _codecs.NONE))
        if _mcoll.supports_codec(collective, algo):
            cds = codecs if codecs is not None else tuple(
                cd for cd in _codecs.codecs() if cd != _codecs.NONE
                and _codecs.admissible(cd, collective, 1.0, integer))
            for cd in cds:
                out.append((algo, 1, cd))
    return tuple(out)


# ---------------------------------------------------------------------------
# selection results + stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Selection:
    """One resolved choice: which algorithm (at what chunk count for the
    pipelined algorithms, with what codec for the compressed ones), at what
    predicted/measured latency, from which evidence source
    ("prior" | "measured")."""
    collective: str
    algo: str
    seconds: float
    source: str
    net: str
    chunks: int = 1
    codec: str = "none"


@dataclasses.dataclass
class SelectionStats:
    """Counts of resolutions by evidence source, plus per-(collective, algo)
    tallies — the observability face of the subsystem (mirrors
    runtime.cache_stats)."""
    prior: int = 0
    measured: int = 0
    by_choice: Dict[Tuple[str, str], int] = dataclasses.field(
        default_factory=dict)

    @property
    def total(self) -> int:
        return self.prior + self.measured

    @property
    def measured_fraction(self) -> float:
        return self.measured / self.total if self.total else 0.0

    def note(self, sel: Selection) -> None:
        if sel.source == "measured":
            self.measured += 1
        else:
            self.prior += 1
        key = (sel.collective, sel.algo)
        self.by_choice[key] = self.by_choice.get(key, 0) + 1

    def reset(self) -> None:
        self.prior = self.measured = 0
        self.by_choice.clear()


# ---------------------------------------------------------------------------
# measured calibration: the persisted tuning table
# ---------------------------------------------------------------------------


def topo_key(topo: Topology) -> str:
    """Stable string key for a topology: shape + per-axis link names.

    Unset links are normalized to the default preset's name, so a bare
    ``Topology(N, P)`` and one explicitly carrying the default preset share
    measurements. (Topologies with *different* resolved links key —
    correctly — to different table rows: calibrate with the same link
    metadata you serve with, e.g. via ``Topology.from_mesh``.)
    """
    inter, intra = topo.link_names
    default = costmodel.resolve_net(None).name
    # mirror net_for's fallback order: a missing link borrows the other
    # level's, then the default preset
    if inter == "default":
        inter = intra if intra != "default" else default
    if intra == "default":
        intra = topo.link_names[0] if topo.link_names[0] != "default" \
            else default
    key = f"{topo.n_nodes}x{topo.n_local}/{inter}/{intra}"
    # sub-communicator topologies get a group suffix so groups calibrate
    # in their own namespace (an 8-way TP group and a 2-way DP group never
    # share rows; siblings of identical shape — same tag — do). Root
    # topologies carry no suffix, so pre-group tables keep resolving.
    if topo.group:
        key += f"/g:{topo.group}"
    return key


class TuningTable:
    """Measured algorithm latencies keyed on
    (topology, collective, dtype, size bucket) -> {algo: seconds}.

    JSON-serialisable so calibration survives processes: benchmarks write it
    once per mesh, serving/training load it at startup.
    """

    VERSION = 1

    def __init__(self, entries: Optional[dict] = None):
        # entries[topo_key][collective][dtype][str(bucket)][algo] = seconds
        self.entries: dict = entries or {}
        # bumped on every mutation so selectors can invalidate memos
        self.generation = 0

    def __len__(self) -> int:
        return sum(len(algos)
                   for colls in self.entries.values()
                   for dts in colls.values()
                   for buckets in dts.values()
                   for algos in buckets.values())

    def record(self, topo: Topology, collective: str, dtype: str,
               nbytes: int, algo: str, seconds: float) -> None:
        b = str(size_bucket(nbytes))
        (self.entries.setdefault(topo_key(topo), {})
             .setdefault(collective, {})
             .setdefault(str(dtype), {})
             .setdefault(b, {}))[algo] = float(seconds)
        self.generation += 1

    def lookup(self, topo: Topology, collective: str, dtype: str,
               nbytes: int) -> Optional[Dict[str, float]]:
        """Measured {algo: seconds} for the exact key, else None."""
        try:
            return self.entries[topo_key(topo)][collective][str(dtype)][
                str(size_bucket(nbytes))]
        except KeyError:
            return None

    def merge(self, other: "TuningTable", reduce=None) -> None:
        """Fold another table's measurements in.

        ``reduce=None`` (default) keeps the historical other-wins-on-
        conflict semantics. A callable ``reduce(mine, theirs)`` resolves
        same-key conflicts instead — cross-process calibration merges pass
        ``max`` because an SPMD collective is only as fast as its slowest
        rank, so the pessimistic timing is the honest one.
        """
        for tk, colls in other.entries.items():
            for coll, dts in colls.items():
                for dt, buckets in dts.items():
                    for b, algos in buckets.items():
                        mine = (self.entries.setdefault(tk, {})
                                    .setdefault(coll, {})
                                    .setdefault(dt, {})
                                    .setdefault(b, {}))
                        if reduce is None:
                            mine.update(algos)
                        else:
                            for algo, sec in algos.items():
                                mine[algo] = (float(sec) if algo not in mine
                                              else float(reduce(mine[algo],
                                                                sec)))
        self.generation += 1

    # -- persistence --------------------------------------------------------

    def to_json(self) -> dict:
        return {"version": self.VERSION, "entries": self.entries}

    @classmethod
    def from_json(cls, obj: dict) -> "TuningTable":
        if obj.get("version") != cls.VERSION:
            raise ValueError(f"tuning table version {obj.get('version')!r} "
                             f"!= {cls.VERSION}")
        return cls(entries=obj.get("entries", {}))

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))

    @classmethod
    def load(cls, path) -> "TuningTable":
        return cls.from_json(json.loads(pathlib.Path(path).read_text()))


# ---------------------------------------------------------------------------
# the selector
# ---------------------------------------------------------------------------


class Selector:
    """Resolves (collective, topology, size, dtype) -> algorithm.

    Measured calibration (exact tuning-table key) beats the cost-model
    prior; the prior covers everything else. Per-instance stats record how
    often each source fired and what was chosen.
    """

    def __init__(self, table: Optional[TuningTable] = None):
        self.table = table if table is not None else TuningTable()
        self.stats = SelectionStats()
        # (collective, topo, bucket, dtype, net) -> Selection; selection
        # granularity is the size bucket, so hot loops pay the cost model /
        # table walk once per bucket, not per call. The whole memo is
        # dropped when the table mutates (generation bump), so it stays
        # bounded by the live key set even across repeated recalibration.
        self._memo: Dict[tuple, Selection] = {}
        self._memo_gen = self.table.generation

    def choose(self, collective: str, topo: Topology, nbytes: int,
               net: Optional[Union[str, NetParams]] = None,
               dtype: str = "float32",
               error_budget: float = 0.0) -> Selection:
        """Return the best Selection for one message (memoized per size
        bucket; stats still count every resolution).

        ``error_budget`` is the caller's accuracy contract: only codecs
        whose stated relative-error bound fits the budget are candidates
        (``0.0`` -> lossless plans only — in both the prior enumeration and
        the measured-table filter, so a calibrated lossy entry can never
        leak into an exact caller's plan). Integer/bool payload dtypes
        force the budget to 0.0 — the compressed execution rejects lossy
        codecs on them — but the lossless integer packers (e.g.
        ``zlib_sim``) remain candidates on non-reducing collectives, so
        token/index payloads can still compress bit-exactly."""
        if self._memo_gen != self.table.generation:
            self._memo.clear()
            self._memo_gen = self.table.generation
        budget = 0.0 if _integer_dtype(dtype) else float(error_budget)
        # key on the raw net spec (None/name/NetParams are all hashable);
        # NetParams resolution happens only on a miss, off the hot path
        key = (collective, topo, size_bucket(nbytes), dtype, net, budget)
        hit = self._memo.get(key)
        if hit is not None:
            self.stats.note(hit)
            return hit
        net_p = (costmodel.net_for(topo) if net is None
                 else costmodel.resolve_net(net))
        cands = candidates(collective, topo)
        if not cands:
            raise ValueError(f"no feasible algorithm for {collective} "
                             f"on {topo_key(topo)}")
        measured = self.table.lookup(topo, collective, dtype, nbytes)
        if measured:
            # entries are plan keys ("algo", "algo#c8", "algo@codec", ...):
            # feasibility is a property of the algorithm part; the codec
            # part must fit the error budget (unknown codec names — e.g. a
            # table from a build with extra codecs — are skipped)
            usable = {}
            for k, s in measured.items():
                algo, ch, cd = decode_plan(k)
                if algo not in cands:
                    continue
                try:
                    if not _codecs.admissible(cd, collective, budget,
                                              _integer_dtype(dtype)):
                        continue
                except ValueError:
                    continue
                usable[k] = s
            if usable:
                plan = min(usable, key=usable.get)
                algo, ch, cd = decode_plan(plan)
                sel = Selection(collective, algo, usable[plan], "measured",
                                net_p.name, ch, cd)
                self._memo[key] = sel
                self.stats.note(sel)
                return sel
        best_algo, best_c, best_cd, best_t = None, 1, _codecs.NONE, \
            float("inf")
        for algo in cands:
            try:
                for cd in codec_candidates(collective, algo, budget, dtype):
                    # chunk candidates under the codec's effective wire
                    # beta: compression shifts the pipelining optimum too
                    cnet = costmodel.codec_net(net_p, topo, cd)
                    for c in chunk_candidates(collective, algo, topo,
                                              nbytes, cnet):
                        t = costmodel.plan_cost(collective, algo, topo,
                                                nbytes, net_p, chunks=c,
                                                codec=cd).time
                        # switch only on a STRICT relative improvement:
                        # model near-ties (e.g. a pipelined variant at
                        # chunks=1 vs its unchunked parent, or a codec at
                        # ratio ~1) must resolve deterministically to the
                        # first, simpler candidate — "none" enumerates
                        # first, so ties stay lossless
                        if best_algo is None or t < best_t * (1 - 1e-9):
                            best_algo, best_c, best_cd, best_t = \
                                algo, c, cd, t
            except ValueError:  # implemented but not modeled: skip the prior
                continue
        if best_algo is None:  # nothing modeled — arbitrary but deterministic
            best_algo, best_c, best_cd, best_t = cands[0], 1, _codecs.NONE, \
                float("inf")
        sel = Selection(collective, best_algo, best_t, "prior", net_p.name,
                        best_c, best_cd)
        self._memo[key] = sel
        self.stats.note(sel)
        return sel

    def crossover_table(self, collective: str, topo: Topology,
                        net: Optional[Union[str, NetParams]] = None,
                        sizes: Optional[Iterable[int]] = None,
                        dtype: str = "float32",
                        error_budget: float = 0.0) -> Dict[int, Selection]:
        """Message size -> Selection over a size sweep (the per-(topo,
        collective) crossover table; ``error_budget`` admits codec plans)."""
        sizes = tuple(sizes) if sizes else tuple(2 ** i for i in range(4, 27))
        return {s: self.choose(collective, topo, s, net=net, dtype=dtype,
                               error_budget=error_budget)
                for s in sizes}

    # -- observed-evidence ingestion (telemetry loop closure) ---------------

    def ingest(self, telemetry=None, min_samples: int = 1) -> int:
        """Fold telemetry's observed per-plan medians into the tuning table
        as measured evidence (opt-in: nothing flows back unless called).

        ``telemetry`` is the ``repro.core.telemetry`` module or any object
        with a ``plan_observations()`` iterable of observation records
        (``topo / collective / dtype / nbytes / plan`` plus
        ``median(synced=True)``). Only synced samples count — dispatch-only
        wall clock must not overwrite blocking calibration rows. Each
        ingested row goes through :meth:`TuningTable.record`, so the
        generation bump invalidates selection memos and the next
        ``choose()`` resolves from the corrected entries — this is how a
        drifted (or poisoned) table row heals from live observation.
        Returns the number of rows recorded."""
        if telemetry is None:
            from repro.core import telemetry  # lazy: telemetry is jax-free
        ingested = 0
        for obs in telemetry.plan_observations():
            if len(obs.samples) < max(1, int(min_samples)):
                continue
            med = obs.median(synced=True)
            if med is None or med <= 0.0:
                continue
            self.table.record(obs.topo, obs.collective, obs.dtype,
                              obs.nbytes, obs.plan, med)
            ingested += 1
        return ingested

    # -- table persistence passthroughs ------------------------------------

    def load_table(self, path) -> None:
        self.table.merge(TuningTable.load(path))

    def save_table(self, path) -> None:
        self.table.save(path)


_DEFAULT = Selector()


def default_selector() -> Selector:
    """The process-wide selector shared by runtime/moe/train/serve."""
    return _DEFAULT


# ---------------------------------------------------------------------------
# original API, now backed by the default selector
# ---------------------------------------------------------------------------


def choose(collective: str, topo: Topology, nbytes: int,
           net: Optional[Union[str, NetParams]] = None) -> Tuple[str, float]:
    """Return (algo, seconds) minimizing modeled/measured latency."""
    sel = _DEFAULT.choose(collective, topo, nbytes, net=net)
    return sel.algo, sel.seconds


def tuning_table(collective: str, topo: Topology,
                 net: Optional[Union[str, NetParams]] = None,
                 sizes: Optional[Tuple[int, ...]] = None) -> Dict[int, str]:
    """Crossover table: message size -> best algorithm name."""
    table = _DEFAULT.crossover_table(collective, topo, net=net, sizes=sizes)
    return {s: sel.algo for s, sel in table.items()}

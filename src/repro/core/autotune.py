"""Algorithm selection: pick the cheapest algorithm for a collective given
message size and topology, using the alpha-beta cost model.

This is the TPU analogue of an MPI library's collective tuning tables —
except derived from the model instead of hand-tuned. `choose` is used by the
framework's manual-collective paths (gradient sync, metric aggregation,
MoE dispatch) with the net preset matching the mesh level the collective
runs over (ICI vs DCN).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core import costmodel
from repro.core.costmodel import NetParams
from repro.core.topology import Topology

_CANDIDATES = {
    "allgather": ("pip_mcoll", "recursive_doubling", "ring", "single_leader",
                  "xla"),
    "scatter": ("pip_mcoll", "binomial", "linear"),
    "allreduce": ("pip_mcoll", "recursive_doubling", "xla"),
}


def choose(collective: str, topo: Topology, nbytes: int,
           net: Optional[NetParams] = None) -> Tuple[str, float]:
    """Return (algo, predicted_seconds) minimizing modeled latency."""
    net = net or costmodel.tpu_v5e_multipod()
    fn = costmodel.COST_FNS[collective]
    best: Tuple[str, float] = ("", float("inf"))
    for algo in _CANDIDATES[collective]:
        if algo == "recursive_doubling" and (topo.world & (topo.world - 1)):
            continue
        t = fn(algo, topo, nbytes, net).time
        if t < best[1]:
            best = (algo, t)
    return best


def tuning_table(collective: str, topo: Topology,
                 net: Optional[NetParams] = None,
                 sizes: Optional[Tuple[int, ...]] = None) -> Dict[int, str]:
    """Crossover table: message size -> best algorithm."""
    sizes = sizes or tuple(2 ** i for i in range(4, 27))
    return {s: choose(collective, topo, s, net)[0] for s in sizes}

"""Collective telemetry: structured tracing, a metrics registry, and
cost-model drift detection for the Communicator stack.

PiP-MColl's argument is about *where time goes* per collective stage; this
module makes the reproduction report that continuously instead of through
one-off benchmark scripts. Three pieces, all **zero-overhead when
disabled** (every instrumentation site in runtime/comm/train/serve guards
on :func:`enabled`, a single module-global read):

  1. **Tracer** — a bounded span ring buffer recording per-collective
     lifecycle events (plan resolution, build/exec cache hit-or-miss, AOT
     compile, persistent-op init/start/wait/release, train-step segments,
     per-bucket overlap windows), tagged with the resolved plan
     ``(collective, algo, chunks, codec, group tag, size bucket)``.
     :func:`export_chrome_trace` emits Chrome/Perfetto trace-event JSON
     (load it at ``ui.perfetto.dev`` or ``chrome://tracing``) so the
     segmented-overlap start/wait windows become a visible timeline:
     compute segments ride the ``main`` track and each in-flight bucket
     rides its own ``comm:*`` track, so overlap shows up as bucket windows
     lying *inside* the enclosing step span.
  2. **Metrics registry** — process-wide counters and fixed-bucket
     histograms (host-side only; instrumentation records on dispatch/wait
     boundaries that already exist and never inserts a device sync).
     :func:`snapshot` unifies the previously scattered
     ``runtime.cache_stats()`` / ``runtime.selection_stats()`` /
     ``comm.live_persistent_ops()`` observables with the registry and the
     per-plan latency observations into one dict.
  3. **Drift detector** — :func:`observe_plan` accumulates per-plan
     wall-clock samples keyed on ``(topology, collective, dtype, size
     bucket, plan)``; :func:`drift_report` compares the observed medians
     against the Selector's measured tuning table and the
     ``costmodel.plan_cost`` prior, flagging plans whose observation
     diverges beyond a threshold. ``Selector.ingest(telemetry)``
     (``core.autotune``) closes the loop by folding observed medians back
     into the table as measured evidence.

Observation kinds: ``synced=True`` samples cover a full
dispatch-to-materialized window (persistent ``wait(block=True)``,
calibration loops) and feed drift/ingest; ``synced=False`` samples are
dispatch-only wall-clock (blocking-method call overhead under async
dispatch) and are kept separately — they land in the histograms but never
in drift verdicts, so async dispatch can't masquerade as a fast plan.

The module imports only the standard library; runtime/comm/autotune are
imported lazily inside :func:`snapshot` / :func:`drift_report`, so every
core module may import this one without cycles.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# enablement: one module-global bool, read by every instrumentation site
# ---------------------------------------------------------------------------

_ENABLED = False
_DEFAULT_CAPACITY = 65536

_LOCK = threading.Lock()


def enabled() -> bool:
    """Whether instrumentation sites record (the hot-path guard)."""
    return _ENABLED


def enable(capacity: Optional[int] = None) -> None:
    """Turn the tracer + plan observation on. ``capacity`` resizes the span
    ring buffer (existing spans are kept up to the new bound)."""
    global _ENABLED, _SPANS
    with _LOCK:
        if capacity is not None and int(capacity) != _SPANS.maxlen:
            _SPANS = deque(_SPANS, maxlen=max(1, int(capacity)))
        _ENABLED = True


def disable() -> None:
    """Turn instrumentation off (recorded spans/metrics are kept until
    :func:`reset`)."""
    global _ENABLED
    _ENABLED = False


def reset() -> None:
    """Drop every recorded span, metric, and plan observation (enablement
    is unchanged) — per-phase assertions start from zero after this."""
    global _DROPPED
    with _LOCK:
        _SPANS.clear()
        _DROPPED = 0
        _REGISTRY.reset()
        _PLAN_OBS.clear()
        _SAMPLE_COUNTERS.clear()


# ---------------------------------------------------------------------------
# tracer: span ring buffer -> Chrome/Perfetto trace JSON
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed lifecycle window. ``start`` is ``time.perf_counter``
    seconds (exported relative to the earliest span); ``track`` is the
    logical timeline lane (``"main"`` for compute/dispatch, ``"comm:*"``
    for in-flight collective windows so concurrent buckets never overlap
    on one lane)."""

    name: str
    cat: str
    start: float
    duration: float
    track: str
    args: Tuple[Tuple[str, Any], ...]

    @property
    def end(self) -> float:
        return self.start + self.duration


_SPANS: "deque[Span]" = deque(maxlen=_DEFAULT_CAPACITY)
_DROPPED = 0


def _emit(span: Span) -> None:
    global _DROPPED
    with _LOCK:
        if len(_SPANS) == _SPANS.maxlen:
            _DROPPED += 1
        _SPANS.append(span)


def _freeze_args(args: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(args.items()))


class _SpanCtx:
    """Context manager emitting one span on exit (enabled path only)."""

    __slots__ = ("name", "cat", "track", "args", "_t0")

    def __init__(self, name, cat, track, args):
        self.name, self.cat, self.track = name, cat, track
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        _emit(Span(self.name, self.cat, self._t0,
                   time.perf_counter() - self._t0, self.track,
                   _freeze_args(self.args)))
        return False


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


def span(name: str, cat: str = "", track: str = "main", **args):
    """``with telemetry.span("compile/allreduce", plan=...):`` — records a
    complete span on exit. Disabled: returns a shared no-op context (no
    allocation beyond the call itself)."""
    if not _ENABLED:
        return _NULL_CTX
    return _SpanCtx(name, cat, track, args)


def begin(name: str, cat: str = "", track: str = "main", **args
          ) -> Optional[tuple]:
    """Open a window that closes in a *different* call frame (persistent-op
    ``start`` -> ``wait``). Returns an opaque token for :func:`end`, or
    ``None`` when disabled (``end(None)`` is a no-op)."""
    if not _ENABLED:
        return None
    return (name, cat, track, _freeze_args(args), time.perf_counter())


def end(token: Optional[tuple]) -> None:
    """Close a :func:`begin` window and record its span."""
    if token is None:
        return
    name, cat, track, args, t0 = token
    _emit(Span(name, cat, t0, time.perf_counter() - t0, track, args))


def emit(name: str, start: float, duration: float, cat: str = "",
         track: str = "main", **args) -> None:
    """Record a span whose window the caller timed itself (hot paths that
    read ``perf_counter`` once and only build tags when enabled)."""
    if not _ENABLED:
        return
    _emit(Span(name, cat, float(start), float(duration), track,
               _freeze_args(args)))


def instant(name: str, cat: str = "", track: str = "main", **args) -> None:
    """A zero-duration marker (cache hit, release, rebind)."""
    if not _ENABLED:
        return
    _emit(Span(name, cat, time.perf_counter(), 0.0, track,
               _freeze_args(args)))


def spans() -> List[Span]:
    """Snapshot of the recorded spans, oldest first."""
    with _LOCK:
        return list(_SPANS)


def spans_dropped() -> int:
    """Spans evicted from the ring buffer since the last :func:`reset`."""
    return _DROPPED


def plan_tags(collective: str, algo: str, chunks: int = 1,
              codec: str = "none", group: str = "",
              nbytes: Optional[int] = None) -> Dict[str, Any]:
    """The canonical span tag dict for one resolved plan — every layer tags
    its spans through this so trace queries see one schema."""
    tags: Dict[str, Any] = {"collective": collective, "algo": algo,
                            "chunks": int(chunks), "codec": codec or "none",
                            "group": group or ""}
    if nbytes is not None:
        tags["size_bucket"] = _bucket(int(nbytes))
    return tags


def export_chrome_trace(path=None) -> dict:
    """Render the span buffer as Chrome trace-event JSON (the format
    Perfetto and ``chrome://tracing`` load). Tracks become named threads of
    one process; spans are complete events (``ph="X"``) with microsecond
    timestamps relative to the earliest recorded span. Returns the dict;
    writes it to ``path`` when given."""
    recorded = spans()
    tracks: Dict[str, int] = {"main": 0}
    for s in recorded:
        tracks.setdefault(s.track, len(tracks))
    epoch = min((s.start for s in recorded), default=0.0)
    events: List[dict] = [
        {"ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
         "args": {"name": track}}
        for track, tid in tracks.items()]
    for s in recorded:
        events.append({
            "name": s.name, "cat": s.cat or "repro", "ph": "X",
            "ts": (s.start - epoch) * 1e6, "dur": s.duration * 1e6,
            "pid": 0, "tid": tracks[s.track], "args": dict(s.args)})
    trace = {"traceEvents": events, "displayTimeUnit": "ms",
             "otherData": {"spans_dropped": _DROPPED}}
    if path is not None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(trace))
    return trace


# ---------------------------------------------------------------------------
# metrics registry: counters + fixed-bucket histograms
# ---------------------------------------------------------------------------


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


#: default histogram bounds: geometric 1 µs .. ~134 s (latencies in
#: seconds); values beyond the last bound land in the overflow bucket
LATENCY_BUCKETS = tuple(1e-6 * 2.0 ** i for i in range(28))


class Histogram:
    """Fixed-bucket histogram: O(len(bounds)) per observe, no allocation.
    Quantiles interpolate within the landing bucket and clamp to the
    observed min/max, so p50/p99 stay meaningful at small counts."""

    __slots__ = ("name", "bounds", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, name: str = "",
                 bounds: Tuple[float, ...] = LATENCY_BUCKETS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        if not self.count:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            lo = self.bounds[i - 1] if i > 0 else 0.0
            hi = self.bounds[i] if i < len(self.bounds) else self.vmax
            if seen + c >= rank:
                frac = max(0.0, min(1.0, (rank - seen) / c))
                est = lo + (hi - lo) * frac
                return max(self.vmin, min(self.vmax, est))
            seen += c
        return self.vmax

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin, "max": self.vmax,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Named counters + histograms, created on first touch."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = LATENCY_BUCKETS) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, bounds)
        return h

    def reset(self) -> None:
        self.counters.clear()
        self.histograms.clear()

    def to_dict(self) -> dict:
        return {"counters": {n: c.value
                             for n, c in sorted(self.counters.items())},
                "histograms": {n: h.summary()
                               for n, h in sorted(self.histograms.items())}}


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry (always live: registry writes are cheap
    host-side increments; only *tracing + plan observation* gate on
    :func:`enabled`)."""
    return _REGISTRY


def counter(name: str) -> Counter:
    return _REGISTRY.counter(name)


def histogram(name: str,
              bounds: Tuple[float, ...] = LATENCY_BUCKETS) -> Histogram:
    return _REGISTRY.histogram(name, bounds)


# ---------------------------------------------------------------------------
# per-plan latency observations (the drift detector's evidence)
# ---------------------------------------------------------------------------

_MAX_SAMPLES = 64


def _bucket(nbytes: int) -> int:
    # power-of-two ceiling, kept in lockstep with autotune.size_bucket
    # (this module stays stdlib-only at import time)
    return 1 << max(0, int(nbytes - 1).bit_length())


@dataclasses.dataclass
class PlanObservation:
    """Bounded wall-clock samples for one resolved plan on one topology.
    ``topo`` is the live (hashable, frozen) Topology so drift/ingest can
    re-enter ``plan_cost`` / ``table.record`` with the exact key."""

    topo: Any
    collective: str
    dtype: str
    nbytes: int
    plan: str
    samples: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=_MAX_SAMPLES))
    dispatch_samples: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=_MAX_SAMPLES))

    def median(self, synced: bool = True) -> Optional[float]:
        buf = self.samples if synced else self.dispatch_samples
        if not buf:
            return None
        vals = sorted(buf)
        n = len(vals)
        mid = vals[n // 2] if n % 2 else (vals[n // 2 - 1]
                                          + vals[n // 2]) / 2.0
        return float(mid)


_PLAN_OBS: Dict[tuple, PlanObservation] = {}


def observe_plan(topo, collective: str, dtype: str, nbytes: int, plan: str,
                 seconds: float, synced: bool = True) -> None:
    """Record one wall-clock sample for a resolved plan (no-op when
    disabled). Called only at boundaries that already exist — calibration
    timing loops and blocking persistent waits (``synced=True``), blocking
    method dispatch windows (``synced=False``) — never by inserting a new
    device sync."""
    if not _ENABLED:
        return
    dtype = str(dtype)
    key = (topo, collective, dtype, _bucket(int(nbytes)), plan)
    with _LOCK:
        obs = _PLAN_OBS.get(key)
        if obs is None:
            obs = _PLAN_OBS[key] = PlanObservation(
                topo, collective, dtype, int(nbytes), plan)
        (obs.samples if synced else obs.dispatch_samples).append(
            float(seconds))
    kind = "sync" if synced else "dispatch"
    _REGISTRY.histogram(
        f"plan.{collective}.{plan}.{kind}_seconds").observe(float(seconds))


def plan_observations() -> List[PlanObservation]:
    """Snapshot of the accumulated per-plan observations."""
    with _LOCK:
        return list(_PLAN_OBS.values())


# -- sampled codec-quality observations (EF carry / achieved ratio) ---------

_SAMPLE_COUNTERS: Dict[str, int] = {}
SAMPLE_EVERY = 16


def should_sample(key: str, every: int = SAMPLE_EVERY) -> bool:
    """Deterministic 1-in-``every`` sampler per key — the gate for
    observations that DO materialize device values (error-feedback carry
    inspection), so the sync cost is paid rarely and only when telemetry
    is on."""
    if not _ENABLED:
        return False
    with _LOCK:
        n = _SAMPLE_COUNTERS.get(key, 0)
        _SAMPLE_COUNTERS[key] = n + 1
    return n % max(1, int(every)) == 0


def observe_ef_error(codec: str, rel_error: float, bound: float) -> None:
    """Record one sampled achieved-vs-bound relative error from an
    error-feedback carry: the residual magnitude relative to the reduced
    payload, next to the codec's stated bound."""
    _REGISTRY.histogram(f"codec.{codec}.ef_rel_error",
                        bounds=tuple(10.0 ** e for e in
                                     range(-12, 3))).observe(rel_error)
    if bound > 0.0 and rel_error > bound:
        _REGISTRY.counter(f"codec.{codec}.ef_bound_exceeded").inc()


def observe_codec_ratio(codec: str, ratio: float) -> None:
    """Record one achieved compression ratio (payload bytes / wire
    bytes)."""
    _REGISTRY.histogram(f"codec.{codec}.achieved_ratio",
                        bounds=tuple(float(2 ** i) / 4.0
                                     for i in range(10))).observe(ratio)


# ---------------------------------------------------------------------------
# snapshot: one dict for the scattered observables
# ---------------------------------------------------------------------------


def _process_rank() -> Tuple[int, int]:
    """(process_index, process_count) of the live runtime — (0, 1) when jax
    is absent/uninitialized, so telemetry stays importable everywhere."""
    try:
        import jax
        return int(jax.process_index()), int(jax.process_count())
    except Exception:
        return 0, 1


def snapshot() -> dict:
    """Unified observability snapshot: cache stats, selection stats, live
    persistent ops, tracer occupancy, registry counters/histograms, and the
    per-plan observation medians.

    Observations are process-local; rows carry this process's rank (and the
    top level a ``process`` block) so rank-0 merges of multi-controller
    snapshots don't alias per-process plan latencies."""
    from repro.core import autotune, comm, runtime  # lazy: no import cycle
    cs = runtime.cache_stats()
    ss = runtime.selection_stats()
    rank, nprocs = _process_rank()
    with _LOCK:
        n_spans = len(_SPANS)
        obs = list(_PLAN_OBS.values())
    out = {
        "enabled": _ENABLED,
        "process": {"index": rank, "count": nprocs},
        "tracer": {"spans": n_spans, "dropped": _DROPPED,
                   "capacity": _SPANS.maxlen},
        "cache": {**dataclasses.asdict(cs),
                  "exec_hit_rate": cs.exec_hit_rate},
        "selection": {"prior": ss.prior, "measured": ss.measured,
                      "total": ss.total,
                      "measured_fraction": ss.measured_fraction,
                      "by_choice": {f"{c}/{a}": n for (c, a), n
                                    in sorted(ss.by_choice.items())}},
        "live_persistent_ops": comm.live_persistent_ops(),
        "plans": [{
            "topology": autotune.topo_key(o.topo),
            "collective": o.collective, "dtype": o.dtype,
            "size_bucket": _bucket(o.nbytes), "plan": o.plan,
            "samples": len(o.samples),
            "observed_median_s": o.median(synced=True),
            "dispatch_samples": len(o.dispatch_samples),
            "dispatch_median_s": o.median(synced=False),
            "rank": rank,
        } for o in obs],
    }
    out.update(_REGISTRY.to_dict())
    return out


# ---------------------------------------------------------------------------
# drift detection: observed medians vs table entries vs cost-model priors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftRow:
    """One plan's observation vs its two references. Signed relative
    drifts are ``(observed - reference) / reference``; ``flagged`` means
    the *measured-table* entry diverges beyond the threshold (the table is
    a promise about this machine — the model is only a prior, reported but
    flagged separately via ``model_flagged`` at its looser threshold)."""

    collective: str
    plan: str
    topology: str
    dtype: str
    size_bucket: int
    samples: int
    observed_s: float
    table_s: Optional[float]
    model_s: Optional[float]
    drift_vs_table: Optional[float]
    drift_vs_model: Optional[float]
    flagged: bool
    model_flagged: bool
    #: process rank the observations were taken on (0 single-process);
    #: merged multi-controller reports keep per-rank rows distinct
    rank: int = 0


def drift_report(selector=None, threshold: float = 0.5,
                 model_threshold: float = 10.0,
                 min_samples: int = 1) -> List[DriftRow]:
    """Compare observed per-plan medians (synced samples only) against the
    selector's measured table and the cost-model prior.

    ``threshold=0.5`` flags a plan whose observed median and table entry
    disagree by more than 1.5x in either direction; ``model_threshold``
    applies the same rule against ``plan_cost`` (much looser: the analytic
    model is not a promise about host-CPU wall clock). Rows come back
    sorted worst-first by table drift magnitude."""
    from repro.core import autotune  # lazy: no import cycle
    sel = selector if selector is not None else autotune.default_selector()
    rank, _ = _process_rank()
    rows: List[DriftRow] = []
    for o in plan_observations():
        if len(o.samples) < max(1, int(min_samples)):
            continue
        observed = o.median(synced=True)
        if not observed or observed <= 0.0:
            continue
        entry = sel.table.lookup(o.topo, o.collective, o.dtype,
                                 o.nbytes) or {}
        table_s = entry.get(o.plan)
        model_s = autotune.predicted_seconds(o.collective, o.plan, o.topo,
                                             o.nbytes)
        drift_t = ((observed - table_s) / table_s
                   if table_s and table_s > 0.0 else None)
        drift_m = ((observed - model_s) / model_s
                   if model_s and model_s > 0.0 else None)

        def _diverged(drift, thresh):
            if drift is None:
                return False
            ratio = 1.0 + drift
            return max(ratio, 1.0 / ratio) > 1.0 + thresh
        rows.append(DriftRow(
            o.collective, o.plan, autotune.topo_key(o.topo), o.dtype,
            _bucket(o.nbytes), len(o.samples), observed, table_s, model_s,
            drift_t, drift_m,
            flagged=_diverged(drift_t, float(threshold)),
            model_flagged=_diverged(drift_m, float(model_threshold)),
            rank=rank))
    rows.sort(key=lambda r: abs(r.drift_vs_table or 0.0), reverse=True)
    return rows


def drifted_plans(selector=None, threshold: float = 0.5,
                  min_samples: int = 1) -> List[DriftRow]:
    """Just the flagged rows of :func:`drift_report`."""
    return [r for r in drift_report(selector, threshold=threshold,
                                    min_samples=min_samples) if r.flagged]

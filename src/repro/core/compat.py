"""Version-portability shims for the JAX APIs the collective runtime needs.

This module is the ONLY place in ``src/`` allowed to reference the
``shard_map`` entry points directly. Everything else goes through
:func:`shard_map` here (usually via ``repro.core.runtime``), so a JAX
upgrade or downgrade is absorbed in exactly one file.

The spelling has moved around across JAX releases:

  * new JAX exposes ``jax.shard_map`` with a ``check_vma`` kwarg,
  * some intermediate releases staged it under ``jax.sharding``,
  * 0.4.x ships ``jax.experimental.shard_map.shard_map`` with the older
    ``check_rep`` kwarg (same meaning: verify the per-device replication /
    varying-manual-axes annotation of the body's outputs).

At import time we resolve which implementation exists and which kwarg
spelling it accepts; :func:`shard_map` translates ``check_vma``⇄``check_rep``
accordingly.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional

import jax
import jax.sharding


def _resolve() -> tuple:
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return "jax", fn
    fn = getattr(jax.sharding, "shard_map", None)
    if fn is not None:
        return "jax.sharding", fn
    from jax.experimental import shard_map as _esm
    return "jax.experimental.shard_map", _esm.shard_map


#: Dotted module path of the implementation picked at import time.
SHARD_MAP_SOURCE, _shard_map_impl = _resolve()

#: Which output-check kwarg the picked implementation accepts
#: ("check_vma", "check_rep", or None if it has neither).
CHECK_KW: Optional[str] = None
_params = inspect.signature(_shard_map_impl).parameters
for _name in ("check_vma", "check_rep"):
    if _name in _params:
        CHECK_KW = _name
        break


def shard_map(f: Callable, mesh, in_specs: Any, out_specs: Any,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None) -> Callable:
    """Version-portable ``shard_map``.

    ``check_vma`` and ``check_rep`` are aliases for the same flag; pass
    whichever spelling you like and it is translated to the one the
    installed JAX accepts (or dropped if the API has neither).
    """
    if check_vma is not None and check_rep is not None:
        raise TypeError("pass only one of check_vma / check_rep")
    check = check_vma if check_vma is not None else check_rep
    kw = {}
    if check is not None and CHECK_KW is not None:
        kw[CHECK_KW] = check
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)

"""Schema for the benchmark artifact (``results/BENCH_collectives.json``).

The artifact is assembled by three cooperating writers —
``measure_collectives.py --calibrate`` (the base sections), ``--overlap``
and ``--codec-kernels`` (merged sections) — driven in sequence by
``benchmarks/run.py calibrate``. A writer that silently drops a section or
renames a row key used to go unnoticed until a reader broke; this module
is the one place the layout is declared, validated both at write time (the
benchmark refuses to emit a malformed artifact) and in the schema
regression test against the committed artifact.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

#: sections ``--calibrate`` writes in one shot; ``backend`` /
#: ``process_count`` record the runtime the numbers were measured under
#: ("single" vs "multiprocess" — see ``repro.distributed.backend``)
CALIBRATE_SECTIONS: Tuple[str, ...] = (
    "topology", "sizes", "backend", "process_count", "table",
    "latency_rows", "model_vs_measured", "pipeline_crossover",
    "compression")

#: sections merged in by the other modes; a full ``run.py calibrate``
#: artifact carries every section
ALL_SECTIONS: Tuple[str, ...] = CALIBRATE_SECTIONS + (
    "overlap", "codec_kernels")

#: required keys per list-of-rows section
ROW_KEYS = {
    "latency_rows": frozenset(
        {"collective", "algo", "nbytes", "dtype", "seconds", "chunks",
         "codec", "group"}),
    "model_vs_measured": frozenset(
        {"collective", "nbytes", "measured_algo", "measured_us",
         "prior_algo", "prior_us", "agree", "per_plan"}),
    "pipeline_crossover": frozenset(
        {"collective", "algo", "model_crossover_bytes", "model_sweep",
         "measured_us_by_plan"}),
    "compression": frozenset(
        {"codec", "declared_ratio", "achieved_ratio", "stated_rel_bound",
         "achieved_abs_error", "bound_abs_tolerance",
         "model_crossover_vs_lossless_bytes",
         "budget_selection_crossover_bytes"}),
}

#: required keys of each ``model_vs_measured[i]["per_plan"]`` row: every
#: measured plan at that (collective, size) with its model prediction and
#: the signed relative error ``(measured - model) / model``
PER_PLAN_KEYS = frozenset(
    {"plan", "measured_us", "model_us", "signed_rel_err"})

#: required keys of the dict-shaped merged sections
SECTION_KEYS = {
    "table": frozenset({"version", "entries"}),
    "overlap": frozenset(
        {"devices", "topology", "microbench", "amortization",
         "train_step"}),
    "codec_kernels": frozenset(
        {"devices", "block", "slices", "world", "elems_per_slice",
         "fused_codecs", "rows", "traffic_halved", "zlib_sim", "note"}),
}


class ArtifactError(ValueError):
    """The artifact is missing a section or a required row key."""


def _require_keys(what: str, obj: dict, required: Iterable[str]) -> None:
    if not isinstance(obj, dict):
        raise ArtifactError(f"{what} must be a dict, got {type(obj).__name__}")
    missing = sorted(set(required) - set(obj))
    if missing:
        raise ArtifactError(f"{what} is missing keys {missing}")


def validate(data: dict, sections: Optional[Tuple[str, ...]] = None) -> dict:
    """Validate ``data`` against the artifact schema and return it.

    ``sections`` names the sections that must be present (default
    :data:`ALL_SECTIONS` — the shape ``run.py calibrate`` commits);
    ``--calibrate`` alone validates with :data:`CALIBRATE_SECTIONS`.
    Sections present beyond the required set are validated too, so a
    partially-merged artifact can't carry a malformed section unnoticed.
    Raises :class:`ArtifactError` on the first violation.
    """
    required = ALL_SECTIONS if sections is None else tuple(sections)
    _require_keys("artifact", data, required)
    if "topology" in data and not isinstance(data["topology"], str):
        raise ArtifactError("topology must be a string topo key")
    if "backend" in data:
        if not isinstance(data["backend"], str) or not data["backend"]:
            raise ArtifactError("backend must be a non-empty string "
                                "(e.g. 'single', 'multiprocess')")
    if "process_count" in data:
        pc = data["process_count"]
        if not isinstance(pc, int) or isinstance(pc, bool) or pc < 1:
            raise ArtifactError("process_count must be an int >= 1")
    if "sizes" in data:
        if (not isinstance(data["sizes"], list) or not data["sizes"]
                or not all(isinstance(s, int) for s in data["sizes"])):
            raise ArtifactError("sizes must be a non-empty list of ints")
    for name, keys in SECTION_KEYS.items():
        if name in data:
            _require_keys(name, data[name], keys)
    for name, keys in ROW_KEYS.items():
        if name not in data:
            continue
        rows = data[name]
        if not isinstance(rows, list) or not rows:
            raise ArtifactError(f"{name} must be a non-empty list of rows")
        for i, row in enumerate(rows):
            _require_keys(f"{name}[{i}]", row, keys)
    if "model_vs_measured" in data:
        for i, row in enumerate(data["model_vs_measured"]):
            pp = row["per_plan"]
            if not isinstance(pp, list) or not pp:
                raise ArtifactError(
                    f"model_vs_measured[{i}].per_plan must be a non-empty "
                    f"list (one row per measured plan)")
            for j, prow in enumerate(pp):
                _require_keys(f"model_vs_measured[{i}].per_plan[{j}]",
                              prow, PER_PLAN_KEYS)
    return data


def validate_file(path, sections: Optional[Tuple[str, ...]] = None) -> dict:
    """Load + :func:`validate` an artifact JSON file."""
    import json
    import pathlib
    return validate(json.loads(pathlib.Path(path).read_text()),
                    sections=sections)

"""Training step: loss, grad, AdamW update — built for pjit over the
production mesh. Microbatch gradient accumulation via lax.scan.

The manual-collective variant (mcoll DP sync + int8 compression) lives in
manual_step.py; this module is the pjit/GSPMD path used by the dry-run."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers.common import Accum
from repro.models import decoder, encdec
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.sharding.rules import constrain


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    z_loss: float = 1e-4
    flags: RunFlags = RunFlags()


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """logits (B,S,V) any dtype, labels (B,S) int32 (-1 = masked).

    fp32 log-softmax; returns (mean_loss, n_tokens)."""
    mask = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    lg = logits.astype(Accum)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    n = jnp.maximum(mask.sum(), 1)
    return jnp.where(mask, nll, 0.0).sum() / n, n


def loss_fn(params, batch, cfg, tcfg: TrainConfig, rules=None, mesh=None):
    flags = tcfg.flags
    if cfg.family == "encdec":
        logits, aux, _ = encdec.forward_train(
            params, batch["frames"], batch["tokens"], cfg,
            rules=rules, mesh=mesh, flags=flags)
    else:
        logits, aux, _ = decoder.forward(
            params, batch["tokens"], cfg, rules=rules, mesh=mesh,
            flags=flags, embeds=batch.get("embeds"))
        if "embeds" in batch and batch["embeds"] is not None:
            # loss only over the token tail (frontend positions are inputs)
            logits = logits[:, batch["embeds"].shape[1]:]
    ce, n = cross_entropy(logits, batch["labels"], tcfg.z_loss)
    moe_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    total = ce + moe_w * aux
    return total, {"ce": ce, "aux": aux, "tokens": n}


def train_step(params, opt_state, batch, cfg, tcfg: TrainConfig,
               rules=None, mesh=None):
    """One optimizer step, optionally over `microbatches` grad-accum slices
    (batch dim 0 must divide)."""
    nmb = tcfg.microbatches

    def grads_of(mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb, cfg, tcfg, rules, mesh)
        return loss, metrics, grads

    if nmb == 1:
        loss, metrics, grads = grads_of(batch)
    else:
        def split(x):
            return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])
        mbs = jax.tree.map(split, batch)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

        def body(carry, mb):
            acc_loss, acc_g = carry
            loss, metrics, grads = grads_of(mb)
            acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / nmb,
                                 acc_g, grads)
            return (acc_loss + loss / nmb, acc_g), metrics

        (loss, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), Accum), zero_g), mbs)
        metrics = jax.tree.map(lambda x: x[-1], metrics)

    new_params, new_opt, opt_metrics = adamw.update(
        params, grads, opt_state, tcfg.optimizer)
    metrics = dict(metrics, **opt_metrics, loss=loss)
    return new_params, new_opt, metrics


def make_jitted_train_step(cfg, tcfg: TrainConfig, mesh, rules,
                           param_shardings, opt_shardings, batch_shardings,
                           donate: bool = True):
    fn = partial(train_step, cfg=cfg, tcfg=tcfg, rules=rules, mesh=mesh)
    return jax.jit(
        fn,
        in_shardings=(param_shardings, opt_shardings, batch_shardings),
        out_shardings=(param_shardings, opt_shardings, None),
        donate_argnums=(0, 1) if donate else ())

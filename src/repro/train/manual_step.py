"""Manual-collective training step: the paper's collectives wired into the
DP gradient-sync path.

Where PiP-MColl fits in training: the per-step *small-message* syncs are
latency-bound at scale — global grad-norm scalars, MoE router load stats,
metric reductions, and (with int8 compression) the compressed-gradient
exchange across the slow pod axis. This module builds a shard_map'd step in
which

  - gradients are synced with mcoll.allreduce (algo selectable:
    pip_mcoll two-level multi-lane | flat recursive doubling | xla psum),
  - optional int8 block-quantized compression with error feedback halves
    the wire bytes across the `node` (slow) axis,
  - scalar metrics use the pip_mcoll path explicitly (the paper's regime).

The pjit path (train.step) remains the default for the dry-run; this path
is validated against it on multi-device CPU meshes in
tests/test_manual_step.py (same loss/grads to fp32 tolerance).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mcoll, runtime
from repro.core.topology import Topology
from repro.optim import adamw, compress
from repro.train.step import TrainConfig, loss_fn


def make_manual_train_step(cfg, tcfg: TrainConfig, mesh, topo: Topology,
                           algo: str = "pip_mcoll",
                           compress_grads: bool = False):
    """Data-parallel over topo.axes (node=slow/pod axis, local=fast axis).
    Params replicated; batch sharded over both axes."""
    ax = (topo.node_axis, topo.local_axis)

    def step(params, opt_state, err_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, tcfg, None, None)

        if compress_grads:
            comp, err_state = compress.compress_tree(grads, err_state)
            qs, scales, treedef = comp
            # int8 payloads sum correctly only after dequant: allreduce the
            # dequantized fp32 (scales ride along) — wire bytes modeled by
            # the cost layer; semantics validated in tests.
            deq = compress.decompress_tree(comp, grads)
            grads = deq
        grads = jax.tree.map(
            lambda g: mcoll.pip_mcoll_allreduce(
                g.astype(jnp.float32).reshape(-1), topo).reshape(g.shape)
            / topo.world if algo == "pip_mcoll" else
            jax.lax.pmean(g, ax), grads)
        loss = mcoll.pip_mcoll_allreduce(
            loss.reshape(1), topo)[0] / topo.world \
            if algo == "pip_mcoll" else jax.lax.pmean(loss, ax)

        new_params, new_opt, om = adamw.update(params, grads, opt_state,
                                               tcfg.optimizer)
        metrics = dict(metrics, **om, loss=loss)
        metrics = {k: (mcoll.pip_mcoll_allreduce(
            jnp.asarray(v, jnp.float32).reshape(1), topo)[0] / topo.world
            if jnp.asarray(v).ndim == 0 else v)
            for k, v in metrics.items()}
        return new_params, new_opt, err_state, metrics

    batch_spec = jax.tree.map(lambda _: P(ax), {"tokens": 0, "labels": 0})

    mapped = runtime.sharded(
        step, mesh,
        in_specs=(P(), P(), P(), P(ax)),
        out_specs=(P(), P(), P(), P()),
        check=False)
    return jax.jit(mapped, donate_argnums=(0, 1, 2))


def init_error_state(params, enabled: bool):
    if not enabled:
        return jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), params)
    return compress.init_error_state(params)

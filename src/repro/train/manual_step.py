"""Manual-collective training step: the paper's collectives wired into the
DP gradient-sync path.

Where PiP-MColl fits in training: the per-step *small-message* syncs are
latency-bound at scale — global grad-norm scalars, MoE router load stats,
metric reductions, and (with int8 compression) the compressed-gradient
exchange across the slow pod axis. This module builds a shard_map'd step in
which

  - gradients are synced with an mcoll allreduce whose algorithm is
    resolved per payload size through the selection subsystem
    (``algo="auto"``, the default: pip_mcoll two-level multi-lane for
    latency-bound sizes, xla/ring for bandwidth-bound ones, per the
    topology's link metadata) — or pinned explicitly via ``algo=``,
  - optional int8 block-quantized compression with error feedback halves
    the wire bytes across the `node` (slow) axis,
  - scalar metrics run through the same selection (small-message regime —
    the paper's headline case).

The pjit path (train.step) remains the default for the dry-run; this path
is validated against it on multi-device CPU meshes in
tests/checks/manual_step_check.py (same loss/grads to fp32 tolerance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import autotune, costmodel, mcoll, runtime
from repro.core.topology import Topology
from repro.optim import adamw, compress
from repro.train.step import TrainConfig, loss_fn


def _make_sync(topo: Topology, algo: str):
    """Mean-allreduce for one payload: ``algo="auto"`` resolves through the
    default selector at trace time (shapes are static, so selection is a
    Python-level decision baked into the jitted step)."""
    net = costmodel.net_for(topo)

    def sync_mean(v):
        g = jnp.asarray(v, jnp.float32).reshape(-1)
        name = algo
        if name == "auto":
            name = autotune.default_selector().choose(
                "allreduce", topo, g.size * g.dtype.itemsize, net=net,
                dtype=str(g.dtype)).algo
        out = mcoll.algorithm("allreduce", name)(g, topo) / topo.world
        return out.reshape(jnp.shape(v))

    return sync_mean


def make_manual_train_step(cfg, tcfg: TrainConfig, mesh, topo: Topology,
                           algo: str = "auto",
                           compress_grads: bool = False):
    """Data-parallel over topo.axes (node=slow/pod axis, local=fast axis).
    Params replicated; batch sharded over both axes. ``algo`` names an
    allreduce algorithm from core.mcoll, or "auto" (default) to let the
    selection subsystem pick one per payload size."""
    sync_mean = _make_sync(topo, algo)

    def step(params, opt_state, err_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, tcfg, None, None)

        if compress_grads:
            comp, err_state = compress.compress_tree(grads, err_state)
            # int8 payloads sum correctly only after dequant: allreduce the
            # dequantized fp32 (scales ride along) — wire bytes modeled by
            # the cost layer; semantics validated in tests.
            grads = compress.decompress_tree(comp, grads)
        grads = jax.tree.map(sync_mean, grads)
        loss = sync_mean(loss.reshape(1))[0]

        new_params, new_opt, om = adamw.update(params, grads, opt_state,
                                               tcfg.optimizer)
        metrics = dict(metrics, **om, loss=loss)
        metrics = {k: (sync_mean(jnp.asarray(v, jnp.float32).reshape(1))[0]
                       if jnp.asarray(v).ndim == 0 else v)
                   for k, v in metrics.items()}
        return new_params, new_opt, err_state, metrics

    mapped = runtime.sharded(
        step, mesh,
        in_specs=(P(), P(), P(), P(topo.axes)),
        out_specs=(P(), P(), P(), P()),
        check=False)
    return jax.jit(mapped, donate_argnums=(0, 1, 2))


def init_error_state(params, enabled: bool):
    if not enabled:
        return jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), params)
    return compress.init_error_state(params)

"""Manual-collective training step: the paper's collectives wired into the
DP gradient-sync path.

Where PiP-MColl fits in training: the per-step *small-message* syncs are
latency-bound at scale — global grad-norm scalars, MoE router load stats,
metric reductions — while the gradient payload itself is the bandwidth-bound
large-message case the paper's segmented transfers target. This module
builds a shard_map'd step in which

  - gradients are synced **bucketed** by default: the whole grad tree is
    flattened into fixed-size buckets (``bucket_bytes``, default 4 MiB) and
    each bucket runs one pipelined allreduce. Bucketing turns many
    per-tensor latency-bound syncs into few large transfers sized where the
    chunked pipeline (``pip_pipeline`` + per-bucket chunk count from the
    selection subsystem) overlaps intra- and inter-node stages,
  - the plan per payload is resolved through the selection subsystem
    (``algo="auto"``, the default) — or pinned explicitly via ``algo=`` /
    ``chunks=`` / ``codec=``,
  - ``error_budget`` opts the gradient sync into error-bounded compression
    (``core.compress``): the selector may pick any codec whose stated
    relative-error bound fits the budget (``0.0`` = lossless plans only),
    and the compressed allreduce threads **error-feedback state** per
    bucket so the accumulated update tracks the true gradient sum,
  - scalar metrics and the loss always sync lossless (small-message regime
    — the paper's headline case — and reported numbers must be exact).

Two step shapes are built here:

  * :func:`make_manual_train_step` — the **fused barrier-style** step: one
    jitted shard_map computing backward, per-bucket allreduce, and the
    optimizer update in a single program (gradient sync happens at the end
    of backprop, every bucket serialized inside one computation). Supports
    error-feedback compressed sync.
  * :func:`make_overlapped_train_step` — the **persistent nonblocking**
    step (the Communicator API's overlap shape): each bucket rides a
    persistent ``comm.allreduce_init`` op (plan resolved + compiled once,
    reused every step). With ``segmented="auto"`` (default, decoder
    family) backprop itself is split into **layer-wise VJP segments**
    aligned to bucket boundaries: the head/chunk/embed backward programs
    run newest-to-oldest and ``op.start(bucket_i)`` is issued *between*
    segment executions, so bucket i's allreduce overlaps bucket i+1's
    backward **compute** — the PiP-MColl overlap shape — instead of only
    its dispatch (the monolithic fallback, one backward program emitting
    all buckets). Compressed buckets thread per-bucket error-feedback
    residuals through **carry ops** (``op.start(x, carry=err)``;
    ``handle.wait() -> (y, new_err)``), matching the fused step's EF
    semantics. The barrier variant of the same decomposition
    (``overlap=False``) waits out each bucket before starting the next —
    the two are bit-identical (same compiled programs, different host
    scheduling), which the check asserts; the benchmark artifact reports
    the step-time delta. ``error_budget`` may be a **schedule**
    ``callable(step) -> float``: the per-bucket codec plan is re-resolved
    only when the budget crosses a plan boundary (old ops released, new
    ops built via the exec cache, so returning to a previous plan never
    recompiles).

The pjit path (train.step) remains the default for the dry-run; this path
is validated against it on multi-device CPU meshes in
tests/checks/manual_step_check.py (same loss/grads to fp32 tolerance, the
bucketed path bit-exact against the unbucketed one, and the compressed
variant still descending).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import autotune, costmodel, mcoll, runtime
from repro.core import compress as codecs
from repro.core import telemetry as _tm
from repro.core.comm import Communicator, communicator
from repro.core.topology import Topology
from repro.optim import adamw
from repro.train.step import TrainConfig, loss_fn

#: default gradient bucket size — large enough that the pipelined allreduce
#: is the modeled winner, small enough to bound the peak fused buffer
DEFAULT_BUCKET_BYTES = 4 << 20


def _comm_topo(mesh, topo) -> Tuple[Communicator, Topology]:
    """Both step builders accept either a :class:`Topology` or a
    :class:`Communicator` (e.g. a ``comm.split(axes=...)`` group child) in
    the ``topo`` slot — the communicator's group then defines the
    data-parallel domain: the batch is sharded and gradients are mean-
    reduced over its axes only, and its tuning rows (group-tagged) drive
    plan selection."""
    if isinstance(topo, Communicator):
        comm = topo
        if comm.mesh is not mesh:
            raise ValueError("the group communicator's mesh must be the "
                             "step's mesh")
        if comm.topo is None:
            raise ValueError("unscoped root communicator: split(axes=...) "
                             "to scope the gradient sync to a group")
        return comm, comm.topo
    return communicator(mesh, topo), topo


def _resolve_plan(topo: Topology, nbytes: int, dtype, algo: str,
                  chunks: Optional[int], codec: Optional[str],
                  error_budget: float) -> Tuple[str, dict]:
    """(algorithm, kwargs) plan for one allreduce payload, resolved at
    trace time (shapes are static, so selection is a Python-level decision
    baked into the jitted step).

    ``algo="auto"`` takes the selector's full (algo, chunks, codec) plan
    under the error budget. A pinned ``algo`` with ``codec=None`` and a
    positive budget still picks the cheapest admissible codec for that
    algorithm via the cost model (so ``algo="pip_mcoll"`` + budget works
    like auto's codec dimension, just with the algorithm fixed)."""
    net = costmodel.net_for(topo)
    name, c, cd = algo, chunks, codec
    if name == "auto":
        sel = autotune.default_selector().choose(
            "allreduce", topo, nbytes, net=net, dtype=str(dtype),
            error_budget=error_budget)
        name = sel.algo
        if c is None:
            c = sel.chunks
        if cd is None:
            cd = sel.codec
    elif cd is None and error_budget > 0.0 and \
            mcoll.supports_codec("allreduce", name):
        cands = codecs.for_budget(error_budget)
        if cands:
            cd = min(cands,
                     key=lambda k: costmodel.plan_cost(
                         "allreduce", name, topo, nbytes, net,
                         chunks=c or 1, codec=k).time)
        # else: no codec admissible under this budget — stay lossless
        # rather than letting min() raise on the empty sequence
    kw = {}
    if c and mcoll.supports_chunks("allreduce", name):
        kw["chunks"] = int(c)
    if cd and cd != codecs.NONE and mcoll.supports_codec("allreduce", name):
        kw["codec"] = cd
    return name, kw


def _make_sync(topo: Topology, algo: str, chunks: Optional[int] = None):
    """Lossless mean-allreduce for one payload (metrics, loss, and the
    unbucketed gradient path)."""

    def sync_mean(v):
        g = jnp.asarray(v, jnp.float32).reshape(-1)
        name, kw = _resolve_plan(topo, g.size * g.dtype.itemsize, g.dtype,
                                 algo, chunks, None, 0.0)
        out = mcoll.algorithm("allreduce", name)(g, topo, **kw) / topo.world
        return out.reshape(jnp.shape(v))

    return sync_mean


def _make_grad_sync(topo: Topology, algo: str, chunks: Optional[int],
                    codec: Optional[str], error_budget: float):
    """Mean-allreduce with error-feedback threading for gradient buckets:
    ``sync(x, err) -> (mean, new_err)``. When the resolved plan is
    lossless (or carries no feedback state), ``err`` passes through."""

    def sync(v, err):
        g = jnp.asarray(v, jnp.float32).reshape(-1)
        name, kw = _resolve_plan(topo, g.size * g.dtype.itemsize, g.dtype,
                                 algo, chunks, codec, error_budget)
        fn = mcoll.algorithm("allreduce", name)
        if kw.get("codec") and err is not None:
            out, err = fn(g, topo, err=err, **kw)
        else:
            out = fn(g, topo, **kw)
        return (out / topo.world).reshape(jnp.shape(v)), err

    return sync


def bucket_slices(total: int, bucket_elems: int) -> List[Tuple[int, int]]:
    """(start, length) windows covering [0, total) in fixed-size buckets
    (the last bucket carries the remainder)."""
    if total <= 0:
        return []
    b = max(1, int(bucket_elems))
    return [(s, min(b, total - s)) for s in range(0, total, b)]


def sync_tree_bucketed(grads, sync_fn, bucket_bytes: int, err_state=None):
    """Flatten a gradient tree into fp32 buckets of ``bucket_bytes``, run
    ``sync_fn(bucket, err) -> (synced, new_err)`` once per bucket, and
    restore the tree structure. Returns ``(synced_tree, new_err_state)``.

    One allreduce per bucket instead of one per tensor: small tensors stop
    paying per-collective latency, and every bucket is large enough for the
    pipelined algorithms to win. Elementwise reductions make the result
    bit-identical to syncing each leaf with the same algorithm.
    ``err_state`` is a tuple of per-bucket error-feedback buffers (from
    :func:`init_error_state`) or empty for lossless sync.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if not leaves:
        return grads, err_state
    flat = (jnp.concatenate(
        [jnp.asarray(l, jnp.float32).reshape(-1) for l in leaves])
        if len(leaves) > 1
        else jnp.asarray(leaves[0], jnp.float32).reshape(-1))
    bucket_elems = max(1, int(bucket_bytes) // 4)  # fp32 wire dtype
    slices = bucket_slices(flat.size, bucket_elems)
    errs = list(err_state) if err_state else [None] * len(slices)
    assert len(errs) == len(slices), \
        f"error state has {len(errs)} buckets, payload needs {len(slices)}"
    synced, new_errs = [], []
    for (start, n), e in zip(slices, errs):
        y, e2 = sync_fn(lax.dynamic_slice_in_dim(flat, start, n, axis=0), e)
        synced.append(y)
        new_errs.append(e2)
    flat = jnp.concatenate(synced) if len(synced) > 1 else synced[0]
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(jnp.shape(l)))
        off += l.size
    new_state = tuple(e for e in new_errs if e is not None)
    return jax.tree_util.tree_unflatten(treedef, out), new_state


def make_manual_train_step(cfg, tcfg: TrainConfig, mesh, topo,
                           algo: str = "auto",
                           error_budget: float = 0.0,
                           bucketed: bool = True,
                           bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                           chunks: Optional[int] = None,
                           codec: Optional[str] = None):
    """Data-parallel over the topology's active axes (node=slow/pod axis,
    local=fast axis). Params replicated; batch sharded over those axes.
    ``topo`` may be a :class:`Topology` or a group :class:`Communicator`
    (``comm.split(axes=...)``) — the group then scopes the sync.

    ``algo`` names an allreduce algorithm from core.mcoll, or "auto"
    (default) to let the selection subsystem pick an (algorithm, chunks,
    codec) plan per payload size. ``error_budget`` admits error-bounded
    codecs into the gradient-sync plan (``0.0`` = lossless; loss/metric
    syncs stay lossless regardless), with error feedback threaded per
    bucket. ``bucketed`` (default) flattens the grad tree into
    ``bucket_bytes`` buckets with one pipelined allreduce each — bit-exact
    with the per-tensor path for the same lossless plan; ``chunks`` /
    ``codec`` pin those knobs instead of the selector's plan. Error
    feedback requires the bucketed path (its state is per bucket); the
    unbucketed path compresses statelessly."""
    _, topo = _comm_topo(mesh, topo)
    sync_mean = _make_sync(topo, algo, chunks)
    grad_sync = _make_grad_sync(topo, algo, chunks, codec, error_budget)

    def bucket_sync(v, e):
        # error buffers are DEVICE state: globally (world, n) sharded over
        # the mesh axes, (1, n) per device inside the shard_map (residuals
        # live at device-dependent offsets, so a replicated spec would lie
        # about the invariant and lose every shard but device 0's on
        # materialization)
        if e is None:
            return grad_sync(v, None)
        y, e2 = grad_sync(v, e[0])
        return y, e2[None]

    def step(params, opt_state, err_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, tcfg, None, None)

        if bucketed:
            grads, err_state = sync_tree_bucketed(grads, bucket_sync,
                                                  bucket_bytes, err_state)
        else:
            grads = jax.tree.map(lambda g: grad_sync(g, None)[0], grads)
        loss = sync_mean(loss.reshape(1))[0]

        new_params, new_opt, om = adamw.update(params, grads, opt_state,
                                               tcfg.optimizer)
        metrics = dict(metrics, **om, loss=loss)
        metrics = {k: (sync_mean(jnp.asarray(v, jnp.float32).reshape(1))[0]
                       if jnp.asarray(v).ndim == 0 else v)
                   for k, v in metrics.items()}
        return new_params, new_opt, err_state, metrics

    ax = topo.active_axes
    err_spec = P(ax) if error_budget > 0.0 else P()
    mapped = runtime.sharded(
        step, mesh,
        in_specs=(P(), P(), err_spec, P(ax)),
        out_specs=(P(), P(), err_spec, P()),
        check=False)
    return jax.jit(mapped, donate_argnums=(0, 1, 2))


def init_error_state(params, error_budget: float = 0.0,
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                     topo: Optional[Topology] = None):
    """Per-bucket error-feedback buffers for the compressed gradient sync:
    a tuple of zero fp32 ``(world, bucket_len)`` arrays (row d = device
    d's residuals; sharded over the mesh axes by the step) matching
    :func:`bucket_slices` over the flattened parameter count. Empty (no
    state) when the budget is 0 — lossless sync carries nothing between
    steps."""
    if error_budget <= 0.0:
        return ()
    if isinstance(topo, Communicator):
        topo = topo.topo
    if topo is None:
        raise ValueError("init_error_state needs the topology when "
                         "error_budget > 0 (error feedback is per-device "
                         "state, shaped (world, bucket_len))")
    total = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    bucket_elems = max(1, int(bucket_bytes) // 4)
    return tuple(jnp.zeros((topo.world, n), jnp.float32)
                 for _, n in bucket_slices(total, bucket_elems))


# ---------------------------------------------------------------------------
# overlapped gradient sync: persistent nonblocking per-bucket allreduce
# ---------------------------------------------------------------------------


class OverlappedGradSync:
    """Per-bucket persistent allreduce ops for the overlapped step.

    Holds one ``PersistentOp`` per gradient bucket plus one for the packed
    scalar-metrics vector (always lossless). ``error_budget`` is a float or
    a schedule ``callable(step) -> float``; plans are re-resolved per step
    but ops are **rebuilt only when a bucket's resolved plan changes**
    (budget crossing a plan boundary) — the old ops are :meth:`released
    <repro.core.comm.PersistentOp.release>` first (rebind hygiene: with
    ``donate=True`` a dropped-but-unreleased op would pin its donated
    buffers), and rebuilding goes through the runtime exec cache, so
    flipping back to an earlier plan is a cache hit, not a recompile.
    ``rebuilds`` counts those transitions.

    Buckets whose resolved plan carries a codec ride **carry ops**
    (``start(x, carry=err) -> handle; wait() -> (y, new_err)``): per-bucket
    error-feedback residuals thread through the persistent op exactly like
    the fused step's ``err_state``, updated on :meth:`wait`. Lossless
    buckets use plain ops (bit-identical to the fused lossless sync path's
    reduction). ``errs`` holds the live per-bucket state (``None`` for
    lossless buckets); it resets to zeros when a plan change rebuilds an
    op.
    """

    def __init__(self, comm, slices: List[Tuple[int, int]], metric_len: int,
                 algo: str = "auto", chunks: Optional[int] = None,
                 codec: Optional[str] = None, error_budget=0.0,
                 donate: bool = False):
        self.comm = comm
        self.slices = list(slices)
        self.metric_len = int(metric_len)
        self.algo, self.chunks, self.codec = algo, chunks, codec
        self.error_budget = error_budget
        self.donate = bool(donate)
        self.rebuilds = 0
        self._plans: Optional[List[Tuple[str, dict]]] = None
        self._last_budget: Optional[float] = None
        self._ops: List = []
        self.errs: List = []
        self._metric_op = None
        self._btokens: List = []  # open per-bucket telemetry windows

    def budget_at(self, step: int) -> float:
        if callable(self.error_budget):
            return float(self.error_budget(int(step)))
        return float(self.error_budget)

    def plans(self) -> List[str]:
        """Current per-bucket plan keys (``algo#cN@codec``)."""
        return [op.plan for op in self._ops]

    def _resolve(self, budget: float) -> List[Tuple[str, dict]]:
        topo = self.comm.topo
        return [_resolve_plan(topo, n * 4, jnp.float32, self.algo,
                              self.chunks, self.codec, budget)
                for _, n in self.slices]

    def ensure_ops(self, step: int) -> None:
        """Re-resolve the per-bucket plan for this step's budget; rebuild
        the persistent ops only when a plan actually changed. Plans are a
        pure function of the budget value here, so an unchanged budget
        (always, for a float knob) skips the cost-model walk entirely."""
        budget = self.budget_at(step)
        if self._plans is not None and budget == self._last_budget:
            return
        self._last_budget = budget
        plans = self._resolve(budget)
        if plans == self._plans:
            return
        for op in self._ops:
            op.release()
        world = self.comm.topo.world
        self._ops = [
            self.comm.allreduce_init(
                shape=(world, n), dtype=jnp.float32, algo=name,
                chunks=kw.get("chunks"), codec=kw.get("codec"),
                donate=self.donate,
                carry=bool(kw.get("codec"))
                and runtime.supports_carry("allreduce", name))
            for (_, n), (name, kw) in zip(self.slices, plans)]
        self.errs = [jnp.zeros(op.shape, jnp.float32) if op.carry else None
                     for op in self._ops]
        if self._metric_op is None:
            # scalar metrics always sync lossless, with the same pinned
            # algorithm family as the gradient plan (budget 0)
            mname, mkw = _resolve_plan(self.comm.topo, self.metric_len * 4,
                                       jnp.float32, self.algo, self.chunks,
                                       None, 0.0)
            self._metric_op = self.comm.allreduce_init(
                shape=(world, self.metric_len), dtype=jnp.float32,
                algo=mname, chunks=mkw.get("chunks"))
        self._btokens = [None] * len(self._ops)
        if self._plans is not None:
            self.rebuilds += 1
            _tm.counter("train.bucket_rebuilds").inc()
            if _tm.enabled():
                _tm.instant("bucket_rebuild", cat="train", step=int(step),
                            budget=budget,
                            plans=",".join(op.plan for op in self._ops))
        self._plans = plans

    # -- per-bucket start/wait (the segmented step interleaves these with
    # its backward-segment programs) ----------------------------------------

    def start(self, i: int, payload):
        """Start bucket ``i``'s persistent allreduce (threading its EF
        carry when the plan compresses); returns the handle."""
        op = self._ops[i]
        if _tm.enabled():
            # the bucket's start->wait window: one lane per bucket, so the
            # trace shows each window nested inside the backward segments
            # it overlaps
            self._btokens[i] = _tm.begin(
                f"bucket{i}[{op.plan}]", cat="bucket", track=f"bucket:{i}",
                bucket=i, **op._tags())
        if op.carry:
            return op.start(payload, carry=self.errs[i])
        return op.start(payload)

    def wait(self, i: int, handle, block: bool = False):
        """Complete bucket ``i``: returns the reduced payload and absorbs
        the new error-feedback state for carry buckets."""
        op = self._ops[i]
        if op.carry:
            y, new_err = handle.wait(block=block)
            self.errs[i] = new_err
            self._close_bucket(i)
            if _tm.should_sample(f"ef:{id(self)}:{i}"):
                self._observe_ef(op, y, new_err)
            return y
        y = handle.wait(block=block)
        self._close_bucket(i)
        return y

    def _close_bucket(self, i: int) -> None:
        if self._btokens and self._btokens[i] is not None:
            _tm.end(self._btokens[i])
            self._btokens[i] = None

    @staticmethod
    def _observe_ef(op, y, new_err) -> None:
        """Sampled codec-quality probe (telemetry on, 1-in-N waits): the
        achieved-vs-bound relative error straight off the error-feedback
        carry, plus the achieved wire ratio on the reduced payload. The
        only telemetry site that materializes device values — which is why
        it hides behind ``should_sample``."""
        amax_y = float(jnp.max(jnp.abs(y)))
        amax_e = float(jnp.max(jnp.abs(new_err)))
        rel = amax_e / (amax_y + 1e-30)
        _tm.observe_ef_error(op.codec, rel,
                             codecs.meta(op.codec).error_bound)
        _tm.observe_codec_ratio(
            op.codec, codecs.codec(op.codec).achieved_ratio(y))

    def run(self, i: int, payload):
        """Barrier-style bucket ``i``: start and block out the wait."""
        return self.wait(i, self.start(i, payload), block=True)

    def start_metric(self, mvec):
        return self._metric_op.start(mvec)

    def sync(self, buckets, mvec, overlap: bool = True):
        """Allreduce every bucket + the metrics vector.

        ``overlap=True``: start everything, then wait — bucket i's
        communication overlaps bucket i+1's dispatch/execution (software
        pipelining under async dispatch). ``overlap=False``: the
        barrier-style reference — each bucket fully completes before the
        next starts. Same ops either way, so results are bit-identical.
        """
        if overlap:
            handles = [self.start(i, b) for i, b in enumerate(buckets)]
            mh = self.start_metric(mvec)
            synced = [self.wait(i, h, block=False)
                      for i, h in enumerate(handles)]
            return synced, mh.wait(block=False)
        synced = [self.run(i, b) for i, b in enumerate(buckets)]
        return synced, self.start_metric(mvec).wait(block=True)


class _OverlappedStep:
    """Callable train step built by :func:`make_overlapped_train_step`.

    Lazily builds its compiled backward/apply programs from the first
    (params, batch) it sees (payload shapes and the metric-key set are
    static from there on).

    Two decompositions (``.mode`` after the first call):

    * ``"monolithic"`` — one backward program emitting every bucket, then
      all per-bucket allreduces. Only the *dispatch* of the allreduces
      overlaps (bucket i's comm vs bucket i+1's dispatch).
    * ``"segmented"`` — backprop is split into layer-wise VJP segments
      aligned to bucket boundaries: a forward program records the hidden
      state at each segment boundary, the head/chunk/embed backward
      programs run newest-to-oldest, and bucket i's persistent allreduce
      **starts between segment executions** — its communication overlaps
      bucket i+1's backward *compute*, the PiP-MColl overlap shape (DDP-
      style gradient bucketing). Available for the decoder family
      (``params`` = embed/groups/final_norm/lm_head, no frontend embeds,
      ``microbatches == 1``); grads match the monolithic decomposition to
      fp32 tolerance but are **not** bitwise against it (segment-shaped
      XLA programs reduce in a different order) — bitwise identity holds
      between the overlap/barrier twins of the *same* decomposition.
    """

    def __init__(self, cfg, tcfg: TrainConfig, mesh, topo,
                 algo: str, error_budget, bucket_bytes: int,
                 chunks: Optional[int], codec: Optional[str],
                 overlap: bool, donate: bool, segmented="auto"):
        self.cfg, self.tcfg = cfg, tcfg
        self.comm, self.topo = _comm_topo(mesh, topo)
        self.mesh = mesh
        self.overlap = bool(overlap)
        self._knobs = (algo, chunks, codec)
        self._budget = error_budget
        self.bucket_bytes = int(bucket_bytes)
        self.donate = bool(donate)
        self.segmented = segmented
        self.mode: Optional[str] = None
        self.grad_sync: Optional[OverlappedGradSync] = None
        self._backward_c = None
        self._apply_c = None
        self._auto_step = 0
        # segmented-mode programs
        self._fwd_c = None
        self._head_bwd_c = None
        self._chunk_bwd_c: List = []
        self._embed_bwd_c = None
        self.bounds: List[Tuple[int, int]] = []

    # -- lazy build ---------------------------------------------------------

    def _segment_support(self, params, batch) -> Optional[str]:
        """None when the segmented decomposition applies, else the reason
        it does not (the decomposition mirrors decoder.forward exactly)."""
        if getattr(self.cfg, "family", None) == "encdec":
            return "encoder-decoder family"
        if self.tcfg.microbatches != 1:
            return "microbatch gradient accumulation"
        if not (isinstance(params, dict)
                and set(params) == {"embed", "groups", "final_norm",
                                    "lm_head"}):
            return "non-decoder parameter tree"
        if isinstance(batch, dict) and batch.get("embeds") is not None:
            return "frontend embeds in the batch"
        return None

    def _build(self, params, batch):
        why_not = self._segment_support(params, batch)
        if self.segmented and why_not is None:
            self.mode = "segmented"
            return self._build_segmented(params, batch)
        if self.segmented is True:
            raise ValueError(
                f"segmented=True but the segmented backward does not "
                f"apply here: {why_not}")
        self.mode = "monolithic"
        return self._build_monolithic(params, batch)

    def _build_monolithic(self, params, batch):
        cfg, tcfg, topo = self.cfg, self.tcfg, self.topo
        leaves = jax.tree.leaves(params)
        treedef = jax.tree.structure(params)
        leaf_meta = [(jnp.shape(l), int(jnp.size(l))) for l in leaves]
        total = sum(s for _, s in leaf_meta)
        slices = bucket_slices(total, max(1, self.bucket_bytes // 4))
        _, metric_avals = jax.eval_shape(
            lambda p, b: loss_fn(p, b, cfg, tcfg, None, None), params, batch)
        mkeys = sorted(k for k, v in metric_avals.items() if not v.shape)
        world, ax = topo.world, topo.active_axes

        def backward(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg, tcfg, None, None)
            ls = jax.tree.leaves(grads)
            flat = (jnp.concatenate(
                [jnp.asarray(l, jnp.float32).reshape(-1) for l in ls])
                if len(ls) > 1
                else jnp.asarray(ls[0], jnp.float32).reshape(-1))
            segs = tuple(lax.dynamic_slice_in_dim(flat, s, n, axis=0)
                         for s, n in slices)
            mvec = jnp.stack(
                [jnp.asarray(loss, jnp.float32)]
                + [jnp.asarray(metrics[k], jnp.float32) for k in mkeys])
            return tuple(g[None] for g in segs) + (mvec[None],)

        self._backward_c = jax.jit(runtime.sharded(
            backward, self.mesh, in_specs=(P(), P(ax)),
            out_specs=(P(ax, None),) * (len(slices) + 1), check=False))

        def apply(params, opt_state, *synced):
            buckets, mvec = synced[:-1], synced[-1]
            parts = [b[0] / world for b in buckets]
            flat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
            out, off = [], 0
            for shape, size in leaf_meta:
                out.append(flat[off:off + size].reshape(shape))
                off += size
            grads = jax.tree_util.tree_unflatten(treedef, out)
            new_params, new_opt, om = adamw.update(params, grads, opt_state,
                                                   tcfg.optimizer)
            mv = mvec[0] / world
            metrics = {k: mv[i + 1] for i, k in enumerate(mkeys)}
            metrics = dict(metrics, **om, loss=mv[0])
            return new_params, new_opt, metrics

        mapped = runtime.sharded(
            apply, self.mesh,
            in_specs=(P(), P()) + (P(ax, None),) * (len(slices) + 1),
            out_specs=(P(), P(), P()), check=False)
        self._apply_c = jax.jit(mapped, donate_argnums=(0, 1))

        algo, chunks, codec = self._knobs
        self.grad_sync = OverlappedGradSync(
            self.comm, slices, len(mkeys) + 1, algo=algo, chunks=chunks,
            codec=codec, error_budget=self._budget, donate=self.donate)

    def _build_segmented(self, params, batch):
        from repro.models import decoder
        from repro.train.step import cross_entropy

        cfg, tcfg, topo = self.cfg, self.tcfg, self.topo
        flags = tcfg.flags
        moe_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
        world, ax = topo.world, topo.active_axes

        # segment boundaries: whole pattern cycles, sized so one chunk's
        # group grads fill ~bucket_bytes (fp32 wire dtype)
        gleaves = jax.tree.leaves(params["groups"])
        gdef = jax.tree.structure(params["groups"])
        nc = int(jnp.shape(gleaves[0])[0])
        cycle_elems = sum(int(jnp.size(l)) // nc for l in gleaves)
        seg = min(nc, max(1, (self.bucket_bytes // 4) // max(1, cycle_elems)))
        bounds = [(lo, min(lo + seg, nc)) for lo in range(0, nc, seg)]
        self.bounds = bounds
        K = len(bounds)

        # per-chunk flat layout: the group leaves sliced to the chunk's
        # cycle window, flattened in tree-leaf order
        def chunk_meta(lo, hi):
            metas = []
            for l in gleaves:
                shape = ((hi - lo),) + tuple(jnp.shape(l)[1:])
                metas.append((shape, int(jnp.size(l)) // nc * (hi - lo)))
            return metas

        head_meta = [(jnp.shape(params["final_norm"]["scale"]),
                      int(jnp.size(params["final_norm"]["scale"]))),
                     (jnp.shape(params["lm_head"]),
                      int(jnp.size(params["lm_head"])))]
        embed_shape = jnp.shape(params["embed"])
        sizes = ([sum(s for _, s in head_meta)]
                 + [sum(s for _, s in chunk_meta(lo, hi))
                    for lo, hi in reversed(bounds)]
                 + [int(jnp.size(params["embed"]))])
        mkeys = ["aux", "ce", "tokens"]  # loss_fn's scalar metrics, sorted

        def _flat32(leaves_):
            parts = [jnp.asarray(l, jnp.float32).reshape(-1) for l in leaves_]
            return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

        # (1) forward: record the hidden state entering every segment
        def fwd(params, batch):
            h = decoder.embed_apply(params, batch["tokens"], cfg)
            hs, aux = [], jnp.zeros((), jnp.float32)
            for lo, hi in bounds:
                hs.append(h)
                h, a = decoder.segment_apply(params, h, cfg, lo, hi,
                                             flags=flags)
                aux = aux + jnp.asarray(a, jnp.float32)
            return tuple(hs) + (h, aux[None])

        self._fwd_c = jax.jit(runtime.sharded(
            fwd, self.mesh, in_specs=(P(), P(ax)),
            out_specs=(P(ax),) * (K + 1) + (P(ax),), check=False))

        # (2) head backward: loss + (final_norm, lm_head) bucket + trunk
        # cotangent + the packed metrics vector
        def head_bwd(params, h_out, aux, batch):
            hp = {"final_norm": params["final_norm"],
                  "lm_head": params["lm_head"]}

            def head_loss(hp_, h_):
                logits = decoder.head_apply(hp_, h_, cfg, flags=flags)
                return cross_entropy(logits, batch["labels"], tcfg.z_loss)

            ce, vjp, n = jax.vjp(head_loss, hp, h_out, has_aux=True)
            dhp, dh = vjp(jnp.ones((), ce.dtype))
            a = aux[0]
            loss = jnp.asarray(ce, jnp.float32) + moe_w * a
            metrics = {"aux": a, "ce": ce, "tokens": n}
            mvec = jnp.stack(
                [loss] + [jnp.asarray(metrics[k], jnp.float32)
                          for k in mkeys])
            return _flat32(jax.tree.leaves(dhp))[None], dh, mvec[None]

        self._head_bwd_c = jax.jit(runtime.sharded(
            head_bwd, self.mesh, in_specs=(P(), P(ax), P(ax), P(ax)),
            out_specs=(P(ax, None), P(ax), P(ax, None)), check=False))

        # (3) one backward program per segment: VJP of that cycle window,
        # emitting its grad bucket + the cotangent for the segment below
        def make_chunk_bwd(lo, hi):
            def chunk_bwd(params, h_in, dh):
                def seg(p, h_):
                    return decoder.segment_apply(p, h_, cfg, lo, hi,
                                                 flags=flags)

                (_, aux_k), vjp_k = jax.vjp(seg, params, h_in)
                dp, dh_in = vjp_k((dh, jnp.asarray(moe_w, aux_k.dtype)))
                gg = jax.tree.map(
                    lambda g: lax.slice_in_dim(g, lo, hi, axis=0),
                    dp["groups"])
                return _flat32(jax.tree.leaves(gg))[None], dh_in

            return jax.jit(runtime.sharded(
                chunk_bwd, self.mesh, in_specs=(P(), P(ax), P(ax)),
                out_specs=(P(ax, None), P(ax)), check=False))

        self._chunk_bwd_c = [make_chunk_bwd(lo, hi) for lo, hi in bounds]

        # (4) embedding backward: the final (oldest) bucket
        def embed_bwd(params, batch, dh0):
            _, vjp_e = jax.vjp(
                lambda p: decoder.embed_apply(p, batch["tokens"], cfg),
                params)
            de = vjp_e(dh0)[0]["embed"]
            return jnp.asarray(de, jnp.float32).reshape(-1)[None]

        self._embed_bwd_c = jax.jit(runtime.sharded(
            embed_bwd, self.mesh, in_specs=(P(), P(ax), P(ax)),
            out_specs=P(ax, None), check=False))

        # (5) apply: reassemble the param-tree grads from the synced
        # buckets (start order: head, chunk_{K-1}..chunk_0, embed)
        cmetas = [chunk_meta(lo, hi) for lo, hi in bounds]

        def unflatten(flat, metas):
            out, off = [], 0
            for shape, size in metas:
                out.append(lax.slice_in_dim(flat, off, off + size,
                                            axis=0).reshape(shape))
                off += size
            return out

        def apply(params, opt_state, *synced):
            buckets, mvec = synced[:-1], synced[-1]
            head = buckets[0][0] / world
            chunks_fwd = [buckets[1 + j][0] / world
                          for j in range(K)][::-1]
            emb = buckets[1 + K][0] / world
            scale_g, lm_g = unflatten(head, head_meta)
            gtrees = [jax.tree_util.tree_unflatten(gdef, unflatten(f, m))
                      for f, m in zip(chunks_fwd, cmetas)]
            ggroups = (jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *gtrees)
                if K > 1 else gtrees[0])
            grads = {"embed": emb.reshape(embed_shape),
                     "final_norm": {"scale": scale_g},
                     "groups": ggroups, "lm_head": lm_g}
            new_params, new_opt, om = adamw.update(params, grads, opt_state,
                                                   tcfg.optimizer)
            mv = mvec[0] / world
            metrics = {k: mv[i + 1] for i, k in enumerate(mkeys)}
            metrics = dict(metrics, **om, loss=mv[0])
            return new_params, new_opt, metrics

        mapped = runtime.sharded(
            apply, self.mesh,
            in_specs=(P(), P()) + (P(ax, None),) * (len(sizes) + 1),
            out_specs=(P(), P(), P()), check=False)
        self._apply_c = jax.jit(mapped, donate_argnums=(0, 1))

        algo, chunks, codec = self._knobs
        self.grad_sync = OverlappedGradSync(
            self.comm, [(0, n) for n in sizes], len(mkeys) + 1, algo=algo,
            chunks=chunks, codec=codec, error_budget=self._budget,
            donate=self.donate)

    # -- the step -----------------------------------------------------------

    def _segmented_step(self, params, opt_state, batch):
        """Backward newest-to-oldest, starting bucket i's allreduce before
        computing segment i+1's backward — under async dispatch bucket i's
        communication runs while the next segment's VJP executes. The
        barrier twin blocks out each bucket before touching the next
        segment (same compiled programs, so the two are bit-identical)."""
        gs, K = self.grad_sync, len(self.bounds)
        with _tm.span("train/step", cat="train", mode="segmented",
                      overlap=self.overlap):
            with _tm.span("train/fwd", cat="train"):
                outs = self._fwd_c(params, batch)
            hs, h_out, aux = outs[:K], outs[K], outs[K + 1]
            with _tm.span("train/head_bwd", cat="train"):
                head_flat, dh, mvec = self._head_bwd_c(params, h_out, aux,
                                                       batch)
            if self.overlap:
                handles = [gs.start(0, head_flat)]
                mh = gs.start_metric(mvec)
                for j, k in enumerate(range(K - 1, -1, -1)):
                    with _tm.span(f"train/chunk_bwd[{k}]", cat="train"):
                        bflat, dh = self._chunk_bwd_c[k](params, hs[k], dh)
                    handles.append(gs.start(1 + j, bflat))
                with _tm.span("train/embed_bwd", cat="train"):
                    eflat = self._embed_bwd_c(params, batch, dh)
                handles.append(gs.start(K + 1, eflat))
                synced = [gs.wait(i, h, block=False)
                          for i, h in enumerate(handles)]
                mvec_s = mh.wait(block=False)
            else:
                synced = [gs.run(0, head_flat)]
                mvec_s = gs.start_metric(mvec).wait(block=True)
                for j, k in enumerate(range(K - 1, -1, -1)):
                    with _tm.span(f"train/chunk_bwd[{k}]", cat="train"):
                        bflat, dh = self._chunk_bwd_c[k](params, hs[k], dh)
                    synced.append(gs.run(1 + j, bflat))
                with _tm.span("train/embed_bwd", cat="train"):
                    eflat = self._embed_bwd_c(params, batch, dh)
                synced.append(gs.run(K + 1, eflat))
            with _tm.span("train/apply", cat="train"):
                return self._apply_c(params, opt_state, *synced, mvec_s)

    def __call__(self, params, opt_state, batch, step: Optional[int] = None):
        """One train step. ``step`` feeds the error-budget schedule (when a
        callable was given); defaults to an internal counter. Returns
        ``(new_params, new_opt_state, metrics)``."""
        if self.mode is None:
            self._build(params, batch)
        if step is None:
            step = self._auto_step
        self._auto_step = int(step) + 1
        self.grad_sync.ensure_ops(int(step))
        if self.mode == "segmented":
            return self._segmented_step(params, opt_state, batch)
        with _tm.span("train/step", cat="train", mode="monolithic",
                      overlap=self.overlap):
            with _tm.span("train/backward", cat="train"):
                outs = self._backward_c(params, batch)
            synced, mvec = self.grad_sync.sync(outs[:-1], outs[-1],
                                               overlap=self.overlap)
            with _tm.span("train/apply", cat="train"):
                return self._apply_c(params, opt_state, *synced, mvec)


def make_overlapped_train_step(cfg, tcfg: TrainConfig, mesh, topo,
                               algo: str = "auto", error_budget=0.0,
                               bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                               chunks: Optional[int] = None,
                               codec: Optional[str] = None,
                               overlap: bool = True,
                               donate: bool = False,
                               segmented="auto") -> _OverlappedStep:
    """Bucketed DP train step with **persistent nonblocking** gradient sync
    (the Communicator overlap shape; see the module docstring).

    Same data-parallel semantics as :func:`make_manual_train_step`
    (bucketed, algo/chunks/codec knobs, loss+scalar-metric sync lossless,
    ``topo`` may be a Topology or a group Communicator from
    ``comm.split``), including error feedback: compressed buckets thread
    per-bucket EF residuals through **carry ops** (``start(x, carry=err)``)
    exactly like the fused step's ``err_state``, so the two paths no
    longer diverge semantically. ``error_budget`` may additionally be a
    schedule ``callable(step) -> float`` (codec plan re-resolved only at
    plan boundaries; ops released and rebuilt through the exec cache).

    ``segmented`` selects the backward decomposition: ``"auto"`` (default)
    uses layer-wise VJP segments when the model supports it — bucket i's
    allreduce then overlaps bucket i+1's backward *compute*, not just its
    dispatch — falling back to the monolithic backward otherwise;
    ``True`` requires it (raises when unsupported); ``False`` pins the
    monolithic shape. The returned step is ``step(params, opt_state,
    batch, step=None) -> (params, opt_state, metrics)``; ``.mode`` names
    the decomposition chosen and ``.grad_sync`` exposes the persistent ops
    (plan keys, rebuild count, EF state) for tests/benchmarks.
    ``overlap=False`` builds the barrier-style variant of the same
    decomposition — bit-identical results, no pipelining.
    """
    return _OverlappedStep(cfg, tcfg, mesh, topo, algo, error_budget,
                           bucket_bytes, chunks, codec, overlap, donate,
                           segmented=segmented)

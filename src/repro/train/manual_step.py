"""Manual-collective training step: the paper's collectives wired into the
DP gradient-sync path.

Where PiP-MColl fits in training: the per-step *small-message* syncs are
latency-bound at scale — global grad-norm scalars, MoE router load stats,
metric reductions — while the gradient payload itself is the bandwidth-bound
large-message case the paper's segmented transfers target. This module
builds a shard_map'd step in which

  - gradients are synced **bucketed** by default: the whole grad tree is
    flattened into fixed-size buckets (``bucket_bytes``, default 4 MiB) and
    each bucket runs one pipelined allreduce. Bucketing turns many
    per-tensor latency-bound syncs into few large transfers sized where the
    chunked pipeline (``pip_pipeline`` + per-bucket chunk count from the
    selection subsystem) overlaps intra- and inter-node stages,
  - the algorithm per payload is resolved through the selection subsystem
    (``algo="auto"``, the default) — or pinned explicitly via ``algo=`` /
    ``chunks=``,
  - optional int8 block-quantized compression with error feedback halves
    the wire bytes across the `node` (slow) axis,
  - scalar metrics run through the same selection (small-message regime —
    the paper's headline case).

The pjit path (train.step) remains the default for the dry-run; this path
is validated against it on multi-device CPU meshes in
tests/checks/manual_step_check.py (same loss/grads to fp32 tolerance, and
the bucketed path bit-exact against the unbucketed one).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import autotune, costmodel, mcoll, runtime
from repro.core.topology import Topology
from repro.optim import adamw, compress
from repro.train.step import TrainConfig, loss_fn

#: default gradient bucket size — large enough that the pipelined allreduce
#: is the modeled winner, small enough to bound the peak fused buffer
DEFAULT_BUCKET_BYTES = 4 << 20


def _make_sync(topo: Topology, algo: str, chunks: Optional[int] = None):
    """Mean-allreduce for one payload: ``algo="auto"`` resolves a full
    (algorithm, chunk count) plan through the default selector at trace
    time (shapes are static, so selection is a Python-level decision baked
    into the jitted step). An explicit ``chunks`` pins the pipelining knob
    for chunk-capable algorithms."""
    net = costmodel.net_for(topo)

    def sync_mean(v):
        g = jnp.asarray(v, jnp.float32).reshape(-1)
        name, c = algo, chunks
        if name == "auto":
            sel = autotune.default_selector().choose(
                "allreduce", topo, g.size * g.dtype.itemsize, net=net,
                dtype=str(g.dtype))
            name = sel.algo
            if c is None:
                c = sel.chunks
        kw = ({"chunks": int(c)}
              if c and mcoll.supports_chunks("allreduce", name) else {})
        out = mcoll.algorithm("allreduce", name)(g, topo, **kw) / topo.world
        return out.reshape(jnp.shape(v))

    return sync_mean


def bucket_slices(total: int, bucket_elems: int) -> List[Tuple[int, int]]:
    """(start, length) windows covering [0, total) in fixed-size buckets
    (the last bucket carries the remainder)."""
    if total <= 0:
        return []
    b = max(1, int(bucket_elems))
    return [(s, min(b, total - s)) for s in range(0, total, b)]


def sync_tree_bucketed(grads, sync_fn, bucket_bytes: int):
    """Flatten a gradient tree into fp32 buckets of ``bucket_bytes``, run
    ``sync_fn`` once per bucket, and restore the tree structure.

    One allreduce per bucket instead of one per tensor: small tensors stop
    paying per-collective latency, and every bucket is large enough for the
    pipelined algorithms to win. Elementwise reductions make the result
    bit-identical to syncing each leaf with the same algorithm.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if not leaves:
        return grads
    flat = (jnp.concatenate(
        [jnp.asarray(l, jnp.float32).reshape(-1) for l in leaves])
        if len(leaves) > 1
        else jnp.asarray(leaves[0], jnp.float32).reshape(-1))
    bucket_elems = max(1, int(bucket_bytes) // 4)  # fp32 wire dtype
    synced = [sync_fn(lax.dynamic_slice_in_dim(flat, start, n, axis=0))
              for start, n in bucket_slices(flat.size, bucket_elems)]
    flat = jnp.concatenate(synced) if len(synced) > 1 else synced[0]
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(jnp.shape(l)))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


def make_manual_train_step(cfg, tcfg: TrainConfig, mesh, topo: Topology,
                           algo: str = "auto",
                           compress_grads: bool = False,
                           bucketed: bool = True,
                           bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                           chunks: Optional[int] = None):
    """Data-parallel over topo.axes (node=slow/pod axis, local=fast axis).
    Params replicated; batch sharded over both axes.

    ``algo`` names an allreduce algorithm from core.mcoll, or "auto"
    (default) to let the selection subsystem pick an (algorithm, chunks)
    plan per payload size. ``bucketed`` (default) flattens the grad tree
    into ``bucket_bytes`` buckets with one pipelined allreduce each —
    bit-exact with the per-tensor path for the same algorithm;
    ``chunks`` pins the pipelining knob instead of the selector's plan."""
    sync_mean = _make_sync(topo, algo, chunks)

    def step(params, opt_state, err_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, tcfg, None, None)

        if compress_grads:
            comp, err_state = compress.compress_tree(grads, err_state)
            # int8 payloads sum correctly only after dequant: allreduce the
            # dequantized fp32 (scales ride along) — wire bytes modeled by
            # the cost layer; semantics validated in tests.
            grads = compress.decompress_tree(comp, grads)
        if bucketed:
            grads = sync_tree_bucketed(grads, sync_mean, bucket_bytes)
        else:
            grads = jax.tree.map(sync_mean, grads)
        loss = sync_mean(loss.reshape(1))[0]

        new_params, new_opt, om = adamw.update(params, grads, opt_state,
                                               tcfg.optimizer)
        metrics = dict(metrics, **om, loss=loss)
        metrics = {k: (sync_mean(jnp.asarray(v, jnp.float32).reshape(1))[0]
                       if jnp.asarray(v).ndim == 0 else v)
                   for k, v in metrics.items()}
        return new_params, new_opt, err_state, metrics

    mapped = runtime.sharded(
        step, mesh,
        in_specs=(P(), P(), P(), P(topo.axes)),
        out_specs=(P(), P(), P(), P()),
        check=False)
    return jax.jit(mapped, donate_argnums=(0, 1, 2))


def init_error_state(params, enabled: bool):
    if not enabled:
        return jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), params)
    return compress.init_error_state(params)

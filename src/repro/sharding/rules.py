"""Logical-axis sharding rules: map named logical axes to mesh axes with
divisibility-aware fallback (replicate when a dim doesn't divide).

Parallelism layout on the production mesh (pod, data, model):
  batch  -> ("pod", "data")   pure DP across pods, DP within pod
  fsdp   -> ("data",)         ZeRO-3 param/optimizer sharding (within pod)
  tp     -> "model"           heads / ffn / experts / vocab
  seq    -> "data"            context parallelism for long-KV decode
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: Tuple[str, ...] = ()
    fsdp: Tuple[str, ...] = ()
    tp: Optional[str] = None
    seq: Optional[str] = None

    def mesh_axes(self):
        out = set(self.batch) | set(self.fsdp)
        if self.tp:
            out.add(self.tp)
        if self.seq:
            out.add(self.seq)
        return out


# logical axis vocabulary
TP_AXES = {"heads", "kv_heads", "ff", "vocab", "experts", "inner"}
BATCH_AXES = {"batch"}
SEQ_AXES = {"seq"}
FSDP_AXES = {"fsdp"}  # the designated big dim of each weight


def _prod(axes: Tuple[str, ...], mesh_shape) -> int:
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return n


def spec_for(logical: Tuple[Optional[str], ...], dims: Tuple[int, ...],
             rules: Rules, mesh_shape) -> P:
    """PartitionSpec for one array. Any logical axis whose mesh assignment
    doesn't evenly divide the dim is replicated instead (recorded by the
    caller if it cares)."""
    assert len(logical) == len(dims), (logical, dims)
    parts = []
    for name, d in zip(logical, dims):
        if name is None:
            parts.append(None)
        elif name in BATCH_AXES:
            ax = tuple(a for a in rules.batch if mesh_shape.get(a, 1) > 1)
            parts.append(ax if ax and d % _prod(ax, mesh_shape) == 0 else None)
        elif name in FSDP_AXES:
            ax = tuple(a for a in rules.fsdp if mesh_shape.get(a, 1) > 1)
            parts.append(ax if ax and d % _prod(ax, mesh_shape) == 0 else None)
        elif name in TP_AXES:
            ax = rules.tp
            ok = ax and mesh_shape.get(ax, 1) > 1 and d % mesh_shape[ax] == 0
            parts.append(ax if ok else None)
        elif name in SEQ_AXES:
            ax = rules.seq
            ok = ax and mesh_shape.get(ax, 1) > 1 and d % mesh_shape[ax] == 0
            parts.append(ax if ok else None)
        else:
            raise ValueError(f"unknown logical axis {name}")
    # PartitionSpec entries that are empty tuples mean replicated; unwrap
    # singleton tuples to the bare axis name (same sharding, canonical form)
    parts = [None if p == () else p for p in parts]
    parts = [p[0] if isinstance(p, tuple) and len(p) == 1 else p
             for p in parts]
    return P(*parts)


def constrain(x, logical: Tuple[Optional[str], ...], rules: Rules, mesh):
    """with_sharding_constraint if a mesh is active; no-op for 1-device runs."""
    if mesh is None or rules is None:
        return x
    spec = spec_for(logical, x.shape, rules, dict(zip(mesh.axis_names,
                                                      mesh.devices.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_specs(logical_tree, shape_tree, rules: Rules, mesh) -> object:
    """Map a pytree of logical tuples + matching ShapeDtypeStructs to
    NamedShardings."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(logical, shp):
        return NamedSharding(mesh, spec_for(logical, shp.shape, rules,
                                            mesh_shape))
    return jax.tree.map(one, logical_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))

"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against, and the fallback path on unsupported backends)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Accum = jnp.float32


def shift_blocks(v, shift):
    """Paper step 6: rotate node-blocks into rank order. v: (N, ...)."""
    return jnp.roll(v, shift, axis=0)


def pack_blocks(src, idx):
    """Multi-object send staging: gather rows. src: (N, m), idx: (K,)."""
    return jnp.take(src, idx, axis=0)


def flash_decode(q, k, v, cur_index):
    """q: (B,1,H,hd); k,v: (B,S,KV,hd); attend to positions < cur_index.
    Returns (B,1,H*hd) fp32."""
    B, _, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=Accum) / (hd ** 0.5)
    valid = jnp.arange(S)[None, None, None, :] < cur_index
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w.astype(v.dtype), v,
                   preferred_element_type=Accum)
    return o.reshape(B, 1, H * hd)


def rwkv6_wkv(r, k, v, w, u, s0):
    """WKV6 recurrence; see repro.layers.rwkv.wkv6_ref."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y
    seq = tuple(x.transpose(1, 0, 2, 3).astype(Accum) for x in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0.astype(Accum), seq)
    return ys.transpose(1, 0, 2, 3), sT


def mamba_scan(dt, A, Bm, Cm, x):
    """Selective SSM scan. dt,x: (B,T,Di) fp32/bf16; A: (Di,N);
    Bm,Cm: (B,T,N). Returns y (B,T,Di) fp32, hT (B,Di,N) fp32."""
    dA = jnp.exp(dt.astype(Accum)[..., None] * A.astype(Accum))
    dBx = (dt.astype(Accum) * x.astype(Accum))[..., None] \
        * Bm.astype(Accum)[:, :, None, :]

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    B, T, Di, N = dA.shape
    h0 = jnp.zeros((B, Di, N), Accum)
    hT, ys = jax.lax.scan(step, h0, (dA.transpose(1, 0, 2, 3),
                                     dBx.transpose(1, 0, 2, 3),
                                     Cm.astype(Accum).transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), hT

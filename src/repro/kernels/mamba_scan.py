"""Pallas selective-scan kernel (Mamba-1, as in Jamba's mamba blocks).

TPU mapping: grid (B, Di/dblk, T/chunk), time innermost; the (dblk, N) SSM
state lives in VMEM scratch across time chunks (no HBM round-trips — the
hardware-aware-scan idea from the Mamba paper mapped to TPU's memory
hierarchy). The channel dim is blocked (dblk) so each program's working set
(chunk x dblk inputs + dblk x N state) fits VMEM; dblk should be a multiple
of 128 for lane alignment on real hardware."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Accum = jnp.float32


def _kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, y_ref, hT_ref, h_ref,
            *, chunk: int, n_chunks: int):
    t_id = pl.program_id(2)

    @pl.when(t_id == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(Accum)                  # (dblk, N)

    def step(i, _):
        dt = dt_ref[0, i].astype(Accum)           # (dblk,)
        bm = b_ref[0, i].astype(Accum)            # (N,)
        cm = c_ref[0, i].astype(Accum)            # (N,)
        x = x_ref[0, i].astype(Accum)             # (dblk,)
        h = h_ref[...]                            # (dblk, N)
        dA = jnp.exp(dt[:, None] * A)
        h = dA * h + (dt * x)[:, None] * bm[None, :]
        h_ref[...] = h
        y_ref[0, i] = (h * cm[None, :]).sum(axis=-1).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(t_id == n_chunks - 1)
    def _flush():
        hT_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "dblk", "interpret"))
def mamba_scan(dt, A, Bm, Cm, x, *, chunk: int = 128, dblk: int = 256,
               interpret: bool = True):
    """dt,x: (B,T,Di); A: (Di,N); Bm,Cm: (B,T,N).
    Returns y (B,T,Di) fp32, hT (B,Di,N) fp32."""
    B, T, Di = dt.shape
    N = A.shape[1]
    chunk = min(chunk, T)
    dblk = min(dblk, Di)
    assert T % chunk == 0 and Di % dblk == 0, (T, chunk, Di, dblk)
    n_chunks = T // chunk
    n_dblk = Di // dblk

    y, hT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(B, n_dblk, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dblk), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((dblk, N), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dblk), lambda b, d, t: (b, t, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dblk), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, dblk, N), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, Di), Accum),
            jax.ShapeDtypeStruct((B, Di, N), Accum),
        ],
        scratch_shapes=[pltpu.VMEM((dblk, N), Accum)],
        interpret=interpret,
    )(dt, A, Bm, Cm, x)
    return y, hT

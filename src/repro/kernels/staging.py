"""Pallas staging kernels for the PiP-MColl collective data paths.

The paper's PiP processes write received fragments straight into the root's
destination buffer (zero-copy shared memory). The TPU analogues are fused
VMEM-tiled copies:

  shift_blocks — paper step 6: rotate the offset-ordered gather buffer into
                 rank order (jnp.roll equivalent). The shift is a runtime
                 value (node index), delivered via scalar prefetch so the
                 BlockSpec index map stays static.
  pack_blocks  — multi-object send staging: gather the rows each lane ships
                 (index list via scalar prefetch).

Both are bandwidth-trivial but latency-critical in the small-message regime
the paper targets — fusing them avoids an extra HBM round-trip between the
collective permute and the consumer."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _shift_kernel(shift_ref, src_ref, o_ref, *, n_blocks: int):
    # out block i <- src block (i - shift) mod N, resolved via the index map
    o_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def shift_blocks(v, shift, *, interpret: bool = True):
    """v: (N, m) (block-major gather buffer); returns roll(v, shift, 0)."""
    N = v.shape[0]
    m = math.prod(v.shape[1:]) or 1
    flat = v.reshape(N, m)
    sh = jnp.asarray(shift, jnp.int32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_shift_kernel, n_blocks=N),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(N,),
            in_specs=[pl.BlockSpec((1, m),
                                   lambda i, sh: ((i - sh[0]) % N, 0))],
            out_specs=pl.BlockSpec((1, m), lambda i, sh: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, m), flat.dtype),
        interpret=interpret,
    )(sh, flat)
    return out.reshape(v.shape)


def _pack_kernel(idx_ref, src_ref, o_ref):
    o_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_blocks(src, idx, *, interpret: bool = True):
    """src: (N, m); idx: (K,) int32 — returns src[idx] as a fused gather."""
    N = src.shape[0]
    m = math.prod(src.shape[1:]) or 1
    flat = src.reshape(N, m)
    K = idx.shape[0]
    out = pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(K,),
            in_specs=[pl.BlockSpec((1, m), lambda i, idx: (idx[i], 0))],
            out_specs=pl.BlockSpec((1, m), lambda i, idx: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((K, m), flat.dtype),
        interpret=interpret,
    )(jnp.asarray(idx, jnp.int32), flat)
    return out.reshape((K,) + src.shape[1:])

"""Pallas flash-decode kernel: one-token GQA attention against a long KV
cache with online softmax and VMEM accumulators.

TPU mapping: grid (B, KV, S/chunk) with the sequence-chunk axis innermost
(sequential on TPU), so the (G, hd) accumulator lives in VMEM scratch across
chunks and K/V stream HBM->VMEM exactly once. `chunk` is the BlockSpec-level
tuning knob (VMEM footprint = 2*chunk*hd*2B + (G,hd) accumulators). The
valid-length index arrives via scalar prefetch so block indexing stays
static. Validated in interpret mode against ref.flash_decode (this container
cannot execute compiled TPU kernels)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Accum = jnp.float32
NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, chunk: int, n_chunks: int, scale: float):
    s_id = pl.program_id(2)

    @pl.when(s_id == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(Accum)                 # (G, hd)
    k = k_ref[0, :, 0].astype(Accum)              # (chunk, hd)
    v = v_ref[0, :, 0].astype(Accum)              # (chunk, hd)
    cur = idx_ref[0]

    pos = s_id * chunk + jax.lax.iota(jnp.int32, chunk)
    s = jnp.dot(q, k.T, preferred_element_type=Accum) * scale  # (G, chunk)
    s = jnp.where((pos < cur)[None, :], s, NEG_INF)

    m_prev = m_ref[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    p = jnp.exp(s - m_new)                         # (G, chunk)
    # fully-masked chunks contribute nothing (exp(NEG_INF - m) ~ 0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=Accum)
    m_ref[...] = m_new

    @pl.when(s_id == n_chunks - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def flash_decode(q, k, v, cur_index, *, chunk: int = 512,
                 interpret: bool = True):
    """q: (B,1,H,hd); k,v: (B,S,KV,hd); positions < cur_index are valid.
    Returns (B,1,H*hd) fp32."""
    B, _, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    qg = q.reshape(B, KV, G, hd)
    idx = jnp.asarray(cur_index, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks,
                          scale=1.0 / hd ** 0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, KV, n_chunks),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, kv, s, idx: (b, kv, 0, 0)),
                pl.BlockSpec((1, chunk, 1, hd),
                             lambda b, kv, s, idx: (b, s, kv, 0)),
                pl.BlockSpec((1, chunk, 1, hd),
                             lambda b, kv, s, idx: (b, s, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, kv, s, idx: (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), Accum),
                pltpu.VMEM((G, 1), Accum),
                pltpu.VMEM((G, hd), Accum),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), Accum),
        interpret=interpret,
    )(idx, qg, k, v)
    return out.reshape(B, 1, H * hd)

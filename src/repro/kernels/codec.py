"""Pallas fused codec kernels for the compressed-collective hot path.

The jnp codecs in ``repro.core.compress`` execute the wire path as separate
streaming passes over the hottest bytes in the system: quantize, ship,
dequantize, add — with error feedback adding a decode and a subtract on
top. Each pass is a full HBM round trip. Following the paper's core claim
(eliminating extra copies/passes is what unlocks message rate) and C-Coll's
observation that codec work sits directly on the wire path, this module
fuses them:

  encode + error-feedback   read the f32 payload (and optionally the carried
                            residual) ONCE; emit the wire blocks, the scales
                            AND the updated residual from registers — the
                            intermediate ``decode(encode(x))`` tensor never
                            materializes in HBM.
  decode + reduce           accumulate the ``W`` incoming wire slices into
                            f32 registers directly (the reduction runs over
                            the grid's inner axis into a revisited output
                            block), replacing dequantize-then-``sum(axis=0)``.

Kernels exist for the ``int8_block``, ``int4_block`` (packed two-per-byte)
and ``fp8_sim`` (when the float8 dtype exists) wire forms. Each is
registered here as a :class:`CodecLowering`; ``core.compress`` routes
``Codec.encode_with_feedback`` / ``encode_residual`` / ``decode_reduce``
through the lowering when ``CodecMeta.fused`` advertises it (and the
module-level fused toggle is on — ``compress.jnp_reference_paths()`` is the
A/B escape hatch conformance uses).

Backend dispatch follows ``kernels/ops.py``: compiled Pallas on TPU,
``interpret=True`` elsewhere — CPU CI runs the same kernel bodies through
the interpreter, so the fused paths are conformance-tested everywhere.

:func:`memory_traffic` is the analytic per-stage HBM byte count (jnp passes
vs fused passes) the codec-kernel microbench and the cost model's
fewer-passes pricing are derived from.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.compress import BLOCK

_FP8_MAX = 448.0  # e4m3 finite max (matches compress.Fp8SimCodec)
_HAVE_FP8 = hasattr(jnp, "float8_e4m3fn")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_blocks(x2d):
    """Pad (S, L) f32 to a whole number of BLOCK-element blocks."""
    x2d = jnp.asarray(x2d).astype(jnp.float32)
    S, L = x2d.shape
    nb = -(-L // BLOCK)
    return jnp.pad(x2d, ((0, 0), (0, nb * BLOCK - L))), nb


# ---------------------------------------------------------------------------
# int8_block: per-256-block int8 + fp32 scale
# ---------------------------------------------------------------------------


def _i8_store(c, q_ref, s_ref, r_ref):
    """Shared body: quantize one (1, BLOCK) block of the corrected payload
    ``c`` and store wire + scale + residual — the same arithmetic as the
    jnp codec (scale = blockmax/127, round-to-nearest, clamped divisor)."""
    scale = jnp.max(jnp.abs(c)) / 127.0
    q = jnp.clip(jnp.round(c / jnp.maximum(scale, 1e-12)), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0, 0] = scale
    r_ref[...] = c - q * scale


def _i8_ef_kernel(x_ref, e_ref, q_ref, s_ref, r_ref):
    _i8_store(x_ref[...] + e_ref[...], q_ref, s_ref, r_ref)


def _i8_enc_kernel(x_ref, q_ref, s_ref, r_ref):
    _i8_store(x_ref[...], q_ref, s_ref, r_ref)


def _i8_dr_kernel(q_ref, s_ref, o_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def _block_encode_call(kernel, inputs, S: int, nb: int, wire_dtype,
                       wire_cols: int, interpret: bool):
    """One fused pass over (S, nb) blocks -> (wire, scale, residual)."""
    n_in = len(inputs)
    return pl.pallas_call(
        kernel,
        grid=(S, nb),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda s, b: (s, b))] * n_in,
        out_specs=[
            pl.BlockSpec((1, wire_cols), lambda s, b: (s, b)),
            pl.BlockSpec((1, 1), lambda s, b: (s, b)),
            pl.BlockSpec((1, BLOCK), lambda s, b: (s, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, nb * wire_cols), wire_dtype),
            jax.ShapeDtypeStruct((S, nb), jnp.float32),
            jax.ShapeDtypeStruct((S, nb * BLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_encode_feedback(x2d, err, *, interpret: bool = True):
    """Fused encode + error feedback: read x and the carried residual once,
    emit ({"q", "scale"}, new residual). Matches the jnp
    ``encode_with_feedback`` contract bit-for-bit in arithmetic."""
    S, L = x2d.shape
    xp, nb = _pad_blocks(x2d)
    ep, _ = _pad_blocks(jnp.asarray(err).astype(jnp.float32))
    q, scale, res = _block_encode_call(_i8_ef_kernel, (xp, ep), S, nb,
                                       jnp.int8, BLOCK, interpret)
    return ({"q": q.reshape(S, nb, BLOCK), "scale": scale}, res[:, :L])


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_encode_residual(x2d, *, interpret: bool = True):
    """Fused encode + round-trip residual (no feedback input)."""
    S, L = x2d.shape
    xp, nb = _pad_blocks(x2d)
    q, scale, res = _block_encode_call(_i8_enc_kernel, (xp,), S, nb,
                                       jnp.int8, BLOCK, interpret)
    return ({"q": q.reshape(S, nb, BLOCK), "scale": scale}, res[:, :L])


@functools.partial(jax.jit, static_argnames=("length", "interpret"))
def int8_decode_reduce(comp, length: int, *, interpret: bool = True):
    """Fused decode + sum over the leading wire-peer axis: accumulate the
    int8 wire slices into an f32 register block per grid column."""
    q3, scale = comp["q"], comp["scale"]
    W, nb = scale.shape
    out = pl.pallas_call(
        _i8_dr_kernel,
        grid=(nb, W),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda b, w: (w, b)),
                  pl.BlockSpec((1, 1), lambda b, w: (w, b))],
        out_specs=pl.BlockSpec((1, BLOCK), lambda b, w: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, nb * BLOCK), jnp.float32),
        interpret=interpret,
    )(q3.reshape(W, nb * BLOCK), scale)
    return out.reshape(-1)[:length]


# ---------------------------------------------------------------------------
# int4_block: packed two-per-byte wire form, per-256-block fp32 scale
# ---------------------------------------------------------------------------

_HALF = BLOCK // 2


def _i4_store(c, q_ref, s_ref, r_ref):
    """Quantize to [-7, 7] against blockmax/7 and pack nibble pairs
    (+8 bias, even element low nibble) — mirrors Int4BlockCodec.encode."""
    scale = jnp.max(jnp.abs(c)) / 7.0
    q = jnp.clip(jnp.round(c / jnp.maximum(scale, 1e-12)), -7, 7)
    r_ref[...] = c - q * scale
    s_ref[0, 0] = scale
    pairs = (q.astype(jnp.int32) + 8).reshape(1, _HALF, 2)
    q_ref[...] = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)


def _i4_ef_kernel(x_ref, e_ref, q_ref, s_ref, r_ref):
    _i4_store(x_ref[...] + e_ref[...], q_ref, s_ref, r_ref)


def _i4_enc_kernel(x_ref, q_ref, s_ref, r_ref):
    _i4_store(x_ref[...], q_ref, s_ref, r_ref)


def _i4_dr_kernel(q_ref, s_ref, o_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    b = q_ref[...].astype(jnp.int32)
    lo = (b & 0xF) - 8
    hi = (b >> 4) - 8
    pair = jnp.stack([lo, hi], axis=-1).reshape(1, BLOCK)
    o_ref[...] += pair.astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def int4_encode_feedback(x2d, err, *, interpret: bool = True):
    S, L = x2d.shape
    xp, nb = _pad_blocks(x2d)
    ep, _ = _pad_blocks(jnp.asarray(err).astype(jnp.float32))
    q, scale, res = _block_encode_call(_i4_ef_kernel, (xp, ep), S, nb,
                                       jnp.uint8, _HALF, interpret)
    return ({"q": q.reshape(S, nb, _HALF), "scale": scale}, res[:, :L])


@functools.partial(jax.jit, static_argnames=("interpret",))
def int4_encode_residual(x2d, *, interpret: bool = True):
    S, L = x2d.shape
    xp, nb = _pad_blocks(x2d)
    q, scale, res = _block_encode_call(_i4_enc_kernel, (xp,), S, nb,
                                       jnp.uint8, _HALF, interpret)
    return ({"q": q.reshape(S, nb, _HALF), "scale": scale}, res[:, :L])


@functools.partial(jax.jit, static_argnames=("length", "interpret"))
def int4_decode_reduce(comp, length: int, *, interpret: bool = True):
    q3, scale = comp["q"], comp["scale"]
    W, nb = scale.shape
    out = pl.pallas_call(
        _i4_dr_kernel,
        grid=(nb, W),
        in_specs=[pl.BlockSpec((1, _HALF), lambda b, w: (w, b)),
                  pl.BlockSpec((1, 1), lambda b, w: (w, b))],
        out_specs=pl.BlockSpec((1, BLOCK), lambda b, w: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, nb * BLOCK), jnp.float32),
        interpret=interpret,
    )(q3.reshape(W, nb * _HALF), scale)
    return out.reshape(-1)[:length]


# ---------------------------------------------------------------------------
# fp8_sim: e4m3 cast against a per-slice scale (whole-slice blocks — the
# scale is a slice-level amax, so the natural fused tile is one slice)
# ---------------------------------------------------------------------------


def _fp8_store(c, q_ref, s_ref, r_ref):
    amax = jnp.max(jnp.abs(c))
    scale = jnp.maximum(amax / _FP8_MAX, 1e-30)
    q = jnp.clip(c / scale, -_FP8_MAX, _FP8_MAX)
    f8 = q.astype(jnp.float8_e4m3fn)
    q_ref[...] = lax.bitcast_convert_type(f8, jnp.uint8)
    s_ref[0, 0] = scale
    r_ref[...] = c - f8.astype(jnp.float32) * scale


def _fp8_ef_kernel(x_ref, e_ref, q_ref, s_ref, r_ref):
    _fp8_store(x_ref[...] + e_ref[...], q_ref, s_ref, r_ref)


def _fp8_enc_kernel(x_ref, q_ref, s_ref, r_ref):
    _fp8_store(x_ref[...], q_ref, s_ref, r_ref)


def _fp8_dr_kernel(q_ref, s_ref, o_ref):
    w = pl.program_id(0)

    @pl.when(w == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    f8 = lax.bitcast_convert_type(q_ref[...], jnp.float8_e4m3fn)
    o_ref[...] += f8.astype(jnp.float32) * s_ref[0, 0]


def _fp8_encode_call(kernel, inputs, S: int, L: int, interpret: bool):
    n_in = len(inputs)
    return pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, L), lambda s: (s, 0))] * n_in,
        out_specs=[
            pl.BlockSpec((1, L), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
            pl.BlockSpec((1, L), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, L), jnp.uint8),
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, L), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fp8_encode_feedback(x2d, err, *, interpret: bool = True):
    S, L = x2d.shape
    x = jnp.asarray(x2d).astype(jnp.float32)
    e = jnp.asarray(err).astype(jnp.float32)
    q, scale, res = _fp8_encode_call(_fp8_ef_kernel, (x, e), S, L, interpret)
    return ({"q": q, "scale": scale.reshape(S)}, res)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fp8_encode_residual(x2d, *, interpret: bool = True):
    S, L = x2d.shape
    x = jnp.asarray(x2d).astype(jnp.float32)
    q, scale, res = _fp8_encode_call(_fp8_enc_kernel, (x,), S, L, interpret)
    return ({"q": q, "scale": scale.reshape(S)}, res)


@functools.partial(jax.jit, static_argnames=("length", "interpret"))
def fp8_decode_reduce(comp, length: int, *, interpret: bool = True):
    q, scale = comp["q"], comp["scale"]
    W, L = q.shape
    out = pl.pallas_call(
        _fp8_dr_kernel,
        grid=(W,),
        in_specs=[pl.BlockSpec((1, L), lambda w: (w, 0)),
                  pl.BlockSpec((1, 1), lambda w: (w, 0))],
        out_specs=pl.BlockSpec((1, L), lambda w: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, L), jnp.float32),
        interpret=interpret,
    )(q, scale.reshape(W, 1))
    return out.reshape(-1)[:length]


# ---------------------------------------------------------------------------
# per-codec lowering registry (what CodecMeta.fused points at)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CodecLowering:
    """The fused entry points for one codec's wire form.

    encode_feedback(x2d, err) -> (comp, new_err)   one pass over x + err
    encode_residual(x2d)      -> (comp, residual)  one pass over x
    decode_reduce(comp, L)    -> (L,) f32          one pass over the wire
    """

    name: str
    encode_feedback: Callable
    encode_residual: Callable
    decode_reduce: Callable


LOWERINGS: Dict[str, CodecLowering] = {}


def _register(lw: CodecLowering) -> CodecLowering:
    LOWERINGS[lw.name] = lw
    return lw


def _dispatch(fn):
    """Bind the backend choice (compiled TPU vs interpret) at call time."""
    def call(*args, **kw):
        return fn(*args, interpret=_interpret(), **kw)
    return call


_register(CodecLowering("int8_block",
                        _dispatch(int8_encode_feedback),
                        _dispatch(int8_encode_residual),
                        _dispatch(int8_decode_reduce)))
_register(CodecLowering("int4_block",
                        _dispatch(int4_encode_feedback),
                        _dispatch(int4_encode_residual),
                        _dispatch(int4_decode_reduce)))
if _HAVE_FP8:
    _register(CodecLowering("fp8_sim",
                            _dispatch(fp8_encode_feedback),
                            _dispatch(fp8_encode_residual),
                            _dispatch(fp8_decode_reduce)))


def lowering(name: str) -> Optional[CodecLowering]:
    """The registered fused lowering for one codec name (None = jnp only)."""
    return LOWERINGS.get(name)


def fused_codec_names() -> Tuple[str, ...]:
    return tuple(sorted(LOWERINGS))


# ---------------------------------------------------------------------------
# analytic memory traffic: jnp passes vs fused passes (the numbers behind
# the cost model's fewer-passes pricing and the codec-kernel microbench)
# ---------------------------------------------------------------------------


def memory_traffic(wire_bytes_per_elem: float, n_elems: int,
                   W: int = 8) -> Dict[str, Dict[str, float]]:
    """HBM bytes moved per stage for ``n_elems`` f32 payload elements.

    jnp encode+feedback: add (r8 w4), encode (r4 w b), decode for the
    residual (r b w4), subtract (r8 w4) — every intermediate round-trips
    HBM. Fused: read x + err once (r8), write wire + residual (w b+4).

    jnp decode+reduce over ``W`` wire slices: dequantize (r b w4) then
    ``sum(axis=0)`` (r4 w 4/W) per wire element. Fused: read the wire
    slices once (r b), accumulate in registers, write f32 once (w 4/W).
    """
    b = float(wire_bytes_per_elem)
    n = float(n_elems)
    return {
        "encode_feedback": {
            "jnp_bytes": n * (8 + 4 + 4 + b + b + 4 + 8 + 4),
            "fused_bytes": n * (8 + b + 4),
        },
        "decode_reduce": {
            "jnp_bytes": n * (b + 4 + 4 + 4.0 / W),
            "fused_bytes": n * (b + 4.0 / W),
        },
    }

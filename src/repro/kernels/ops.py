"""Jitted public wrappers for the Pallas kernels with backend dispatch:
compiled Pallas on TPU, interpret mode elsewhere (this container), pure-jnp
ref as the always-available fallback/oracle."""
from __future__ import annotations

import jax

from repro.kernels import flash_decode as _fd
from repro.kernels import mamba_scan as _ms
from repro.kernels import rwkv6_wkv as _rw
from repro.kernels import staging as _st
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_decode(q, k, v, cur_index, chunk: int = 512):
    S = k.shape[1]
    if S % min(chunk, S):
        return ref.flash_decode(q, k, v, cur_index)
    return _fd.flash_decode(q, k, v, cur_index, chunk=chunk,
                            interpret=_interpret())


def rwkv6_wkv(r, k, v, w, u, s0, chunk: int = 128):
    T = r.shape[1]
    if T % min(chunk, T):
        return ref.rwkv6_wkv(r, k, v, w, u, s0)
    return _rw.rwkv6_wkv(r, k, v, w, u, s0, chunk=chunk,
                         interpret=_interpret())


def mamba_scan(dt, A, Bm, Cm, x, chunk: int = 128, dblk: int = 256):
    T, Di = dt.shape[1], dt.shape[2]
    if T % min(chunk, T) or Di % min(dblk, Di):
        return ref.mamba_scan(dt, A, Bm, Cm, x)
    return _ms.mamba_scan(dt, A, Bm, Cm, x, chunk=chunk, dblk=dblk,
                          interpret=_interpret())


def shift_blocks(v, shift):
    return _st.shift_blocks(v, shift, interpret=_interpret())


def pack_blocks(src, idx):
    return _st.pack_blocks(src, idx, interpret=_interpret())

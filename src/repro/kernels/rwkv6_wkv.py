"""Pallas WKV6 kernel (RWKV-6 Finch recurrence) — chunked over time with the
per-head (hd, hd) state held in VMEM scratch across chunks.

TPU mapping: grid (B, H, T/chunk); the time-chunk axis is innermost
(sequential), so state S never round-trips HBM between chunks — the paper's
"keep staging in shared memory" idea applied to recurrent state. Within a
chunk a fori_loop runs the exact recurrence; chunk length trades VMEM
footprint (4 x chunk x hd inputs) against grid overhead."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Accum = jnp.float32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_ref,
            *, chunk: int, n_chunks: int):
    t_id = pl.program_id(2)

    @pl.when(t_id == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(Accum)

    u = u_ref[0].astype(Accum)                    # (hd,)

    def step(i, _):
        r = r_ref[0, i, 0].astype(Accum)          # (hd,)
        k = k_ref[0, i, 0].astype(Accum)
        v = v_ref[0, i, 0].astype(Accum)
        w = w_ref[0, i, 0].astype(Accum)
        S = s_ref[...]                            # (hd, hd)
        kv = k[:, None] * v[None, :]
        y = ((S + u[:, None] * kv) * r[:, None]).sum(axis=0)
        y_ref[0, i, 0] = y.astype(y_ref.dtype)
        s_ref[...] = w[:, None] * S + kv
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(t_id == n_chunks - 1)
    def _flush():
        sT_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, s0, *, chunk: int = 128, interpret: bool = True):
    """r,k,v,w: (B,T,H,hd) (w = decay in (0,1), fp32-safe); u: (H,hd);
    s0: (B,H,hd,hd). Returns y (B,T,H,hd) fp32, sT (B,H,hd,hd) fp32."""
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk

    y, sT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, hd), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, hd), Accum),
            jax.ShapeDtypeStruct((B, H, hd, hd), Accum),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), Accum)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT

"""Batched serving engine: continuous prefill+decode over a request queue.

Serving loop structure (vLLM-style, reduced):
  - requests arrive with a prompt (token array) and max_new_tokens,
  - the engine packs up to `max_batch` active sequences into one fixed
    KV-cache block (padded slots are masked),
  - one prefill pass per admitted request fills its cache rows,
  - a single fused decode step advances every active sequence each tick;
    finished sequences (EOS or budget) free their slot for the next queue
    entry (continuous batching).

Token-level sync across DP replicas (multi-host) is a small-message
collective — the paper's regime. When the engine is given a mesh/topology
it binds a ``Communicator`` (``repro.core.comm``) — and, with
``sync_axes=...``, scopes the sync to a sub-communicator
(``comm.split(axes=sync_axes)``, e.g. the DP group of a DPxTP mesh) — and
syncs each tick's sampled tokens through a **persistent broadcast op**: the
tick payload
shape is fixed at ``(max_batch,)``, so the ``(algo, chunks, codec)`` plan
is resolved and the executable compiled once on the first tick
(``comm.broadcast_init``), and every later tick is a bare
``op.start(...).wait()`` — no cache lookups on the serving hot path. The
algorithm comes from the selection subsystem (``algo="auto"``: cost-model
prior until a calibration table is loaded, measured table after — the op
re-resolves when the tuning table mutates, tracked by generation). The
engine exposes ``sync_error_budget`` — the subsystem-wide accuracy knob —
on that plan resolution (integer token payloads always resolve lossless;
see ``Engine.__init__``)."""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.comm import Communicator, PersistentOp
from repro.core.topology import Topology
from repro.models import decoder
from repro.models.decoder import RunFlags

#: sync-plan rebinds (tuning-table generation changes) tolerated silently;
#: past this, one rate-limited warning names the storm so the flat
#: ``live_persistent_ops()`` assertion has a diagnostic to point at
REBIND_WARN_THRESHOLD = 3


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: Optional[List[int]] = None


class Engine:
    def __init__(self, params, cfg, max_batch: int = 8, max_len: int = 256,
                 flags: RunFlags = RunFlags(), greedy: bool = True,
                 mesh=None, topo: Optional[Topology] = None,
                 sync_axes=None, sync_algo: str = "auto",
                 sync_error_budget: float = 0.0):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.flags = flags
        # DP replica token sync: algorithm resolved per tick payload by the
        # selection subsystem (sync_algo="auto"), or pinned explicitly.
        # sync_error_budget is the engine's accuracy knob on that plan: it
        # flows into the selector's codec gating (core.compress); integer
        # token payloads resolve lossless for any budget (lossy codecs are
        # inadmissible on integers), but the knob is part of the engine API
        # so float-payload syncs (logit / hidden-state replication) inherit
        # the budget semantics.
        # sync_axes scopes the tick sync to a sub-communicator —
        # ``comm.split(axes=sync_axes)`` — e.g. sync_axes="node" broadcasts
        # within each DP replica group while TP shards stay independent.
        # Calibration for the sync plan then belongs on ``self.sync_comm``
        # (the group's tuning rows are namespaced by the group tag).
        self.mesh = mesh
        # Communicator(mesh, None) derives the default node/local topology
        # when the mesh has those axes, and is an *unscoped root* (topo
        # None) otherwise — split(axes=...) still works on it, so
        # sync_axes= remains the way to serve on e.g. a 3-axis MoE mesh.
        self.comm = (Communicator(mesh, topo) if mesh is not None else None)
        self.topo = self.comm.topo if self.comm is not None else topo
        self.sync_comm = (self.comm.split(axes=sync_axes)
                          if self.comm is not None and sync_axes is not None
                          else self.comm)
        if mesh is not None and (self.sync_comm is None
                                 or self.sync_comm.topo is None):
            # fail at construction, not on the first mid-serving tick: an
            # unscoped root would slip past _sync_tokens' world-1 guard and
            # blow up inside broadcast_init with a live batch in flight
            raise ValueError(
                f"engine tick-sync needs a scoped communicator: mesh axes "
                f"{tuple(mesh.axis_names)} do not map onto the default "
                f"node/local topology. Pass sync_axes=<axis or (axis, "
                f"axis)> so the engine scopes the sync via comm.split("
                f"axes=...), or pass an explicit topo=.")
        self.sync_algo = sync_algo
        self.sync_error_budget = float(sync_error_budget)
        # lazily bound on the first real sync (a world-1 engine never pays
        # for plan resolution or compilation — see _sync_tokens); rebound
        # when the selector's tuning table mutates, so a calibration table
        # loaded mid-serving still flips auto to the measured plan
        self._sync_op: Optional[PersistentOp] = None
        self._sync_gen: int = -1
        # per-engine observability: tick latency histogram (host-side,
        # timed around the whole decode+sync tick — no extra device sync),
        # slot-occupancy accumulator, and the sync-plan rebind counter
        # behind Engine.metrics(). Always on: one perf_counter pair and a
        # histogram bump per tick is noise next to a decode step.
        self._tick_hist = telemetry.Histogram("serve.tick_seconds")
        self._ticks = 0
        self._occupied_slot_ticks = 0
        self.rebinds = 0
        self._rebind_warned = False
        self.caches = decoder.init_cache(cfg, max_batch, max_len)
        self.lengths = np.zeros(max_batch, np.int32)
        self.active: List[Optional[Request]] = [None] * max_batch

        def prefill(params, caches, tokens):
            logits, _, new_c = decoder.forward(params, tokens, cfg,
                                               flags=flags, caches=caches)
            return logits[:, -1:], new_c

        def decode(params, caches, tokens, index):
            logits, _, new_c = decoder.forward(params, tokens, cfg,
                                               flags=flags, caches=caches,
                                               cache_index=index)
            return logits, new_c

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _sync_tokens(self, nxt: np.ndarray) -> np.ndarray:
        """Cross-replica agreement on each slot's next token (greedy decode
        is deterministic, but sampled decode diverges across hosts without
        this). Small-message broadcast — the paper's latency-bound regime —
        through a persistent op: plan + executable fixed on the first tick,
        every later tick a bare start/wait."""
        if self.mesh is None or (self.sync_comm.topo is not None
                                 and self.sync_comm.topo.world == 1):
            return nxt  # nothing to reconcile; skip the per-token dispatch
        arr = jnp.asarray(nxt, jnp.int32)
        gen = self.sync_comm.selector.table.generation
        if self._sync_op is None or gen != self._sync_gen:
            # (re)resolve the plan: first tick, or the tuning table changed
            # (e.g. a calibration table loaded mid-serving) — re-init is an
            # exec-cache hit when the resolved plan is unchanged. Release
            # the op being replaced (rebind hygiene: an orphaned op would
            # linger in the live-op count and pin donated buffers).
            if self._sync_op is not None:
                self._sync_op.release()
                # a *re*bind (not the first bind): a storm of these —
                # e.g. a budget schedule oscillating the tuning table
                # every tick — used to be completely silent
                self.rebinds += 1
                telemetry.counter("serve.plan_rebinds").inc()
                if (self.rebinds > REBIND_WARN_THRESHOLD
                        and not self._rebind_warned):
                    self._rebind_warned = True
                    warnings.warn(
                        f"engine sync-plan rebind storm: {self.rebinds} "
                        f"rebinds over {self._ticks} ticks (tuning-table "
                        f"generation now {gen}); something is mutating the "
                        f"selector table every few ticks — each rebind "
                        f"releases and re-inits the persistent sync op "
                        f"(exec-cache hits, but plan resolution per tick). "
                        f"See Engine.metrics()['plan_rebinds'].",
                        RuntimeWarning, stacklevel=3)
            self._sync_op = self.sync_comm.broadcast_init(
                arr, algo=self.sync_algo,
                error_budget=self.sync_error_budget)
            self._sync_gen = gen
        return np.asarray(self._sync_op.start(arr).wait(block=False)[0])

    # NOTE: slot-at-a-time prefill keeps the demo simple; the fused decode
    # step is the performance-relevant path.
    def _admit(self, req: Request, slot: int):
        T = len(req.prompt)
        assert T < self.max_len
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        # run prefill on a single-row cache view, then write it back
        # (cache leaves are (n_cycles, batch, ...): batch is dim 1)
        row = jax.tree.map(lambda c: c[:, slot:slot + 1], self.caches)
        last_logits, row = self._prefill(self.params, row, tokens)
        self.caches = jax.tree.map(
            lambda c, r: c.at[:, slot:slot + 1].set(r), self.caches, row)
        self.lengths[slot] = T
        req.out_tokens = [int(last_logits[0, 0].argmax())]
        self.active[slot] = req

    def run(self, requests: List[Request], max_ticks: int = 10000
            ) -> List[Request]:
        queue = list(requests)
        done: List[Request] = []
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            ticks += 1
            t_tick = time.perf_counter()
            # admit
            for slot in range(self.max_batch):
                if self.active[slot] is None and queue:
                    self._admit(queue.pop(0), slot)
            # fused decode tick: every active slot advances one token, each
            # at its OWN cache index (a (B,) vector): slot b's new KV row
            # lands at lengths[b] and its attention masks to lengths[b]+1.
            # A uniform max index would jump a freshly admitted short row
            # past its true length, leaving uninitialized KV it then
            # attends over (mixed-length admission corruption).
            toks = np.zeros((self.max_batch, 1), np.int32)
            for slot, req in enumerate(self.active):
                if req is not None:
                    toks[slot, 0] = req.out_tokens[-1]
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(self.lengths, jnp.int32))
            nxt = self._sync_tokens(np.asarray(logits[:, 0].argmax(-1)))
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                req.out_tokens.append(int(nxt[slot]))
                self.lengths[slot] += 1
                if (len(req.out_tokens) >= req.max_new_tokens or
                        (req.eos_id is not None
                         and req.out_tokens[-1] == req.eos_id)):
                    done.append(req)
                    self.active[slot] = None
            dt = time.perf_counter() - t_tick
            active_n = sum(r is not None for r in self.active)
            self._ticks += 1
            self._occupied_slot_ticks += active_n
            self._tick_hist.observe(dt)
            telemetry.emit("serve/tick", t_tick, dt, cat="serve",
                           active=active_n)
        done.extend([r for r in self.active if r is not None])
        return done

    def metrics(self) -> dict:
        """Per-engine serving metrics: tick-latency distribution (p50/p99
        seconds over every decode+sync tick this engine has run), mean slot
        occupancy (active slots / max_batch, post-retire), and the
        sync-plan rebind count (see ``REBIND_WARN_THRESHOLD``)."""
        h = self._tick_hist
        return {
            "ticks": self._ticks,
            "tick_p50_s": h.quantile(0.50),
            "tick_p99_s": h.quantile(0.99),
            "tick_mean_s": h.mean,
            "slot_occupancy": (self._occupied_slot_ticks
                               / (self._ticks * self.max_batch)
                               if self._ticks else 0.0),
            "plan_rebinds": self.rebinds,
            "sync_starts": (self._sync_op.starts
                            if self._sync_op is not None else 0),
        }

"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

Hardware constants (TPU v5e target, per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
HLO_FLOPs/bytes come from the trip-count-weighted HLO analysis (hlo.py) of
the post-SPMD compiled module; both are PER-DEVICE quantities, so `chips`
does not divide them again — the formulas below therefore use per-chip
peaks directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (intra-pod)
DCN_BW = 25e9              # bytes/s / host (pod axis)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str
    collective_counts: Dict[str, int]

    def total_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction: time the chips *must* spend on
        model FLOPs vs the bound (max term)."""
        ideal = self.compute_s * self.useful_ratio
        return ideal / self.total_s() if self.total_s() > 0 else 0.0


def compute_terms(hlo_flops_per_dev: float, hlo_bytes_per_dev: float,
                  collective_bytes_per_dev: float, chips: int,
                  model_flops_global: float,
                  collective_counts: Optional[Dict[str, int]] = None,
                  link_bw: float = ICI_BW) -> RooflineTerms:
    compute_s = hlo_flops_per_dev / PEAK_FLOPS
    memory_s = hlo_bytes_per_dev / HBM_BW
    coll_s = collective_bytes_per_dev / link_bw
    useful = (model_flops_global / (hlo_flops_per_dev * chips)
              if hlo_flops_per_dev else 0.0)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(compute_s, memory_s, coll_s, hlo_flops_per_dev,
                         hlo_bytes_per_dev, collective_bytes_per_dev,
                         model_flops_global, useful, bottleneck,
                         collective_counts or {})


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D for inference,
    with N = active params; D = tokens processed this step. (Reported as-is
    per the assignment formula; attention-matmul FLOPs are reported
    separately via model_flops_attn for the useful-ratio diagnostic.)"""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def _n_attn_layers(cfg) -> int:
    pat = cfg.block_pattern
    per_cycle = sum(1 for k in pat if k == "attn")
    return cfg.n_layers // len(pat) * per_cycle


def model_flops_attn(cfg, shape) -> float:
    """Attention score+value matmul FLOPs (excluded from 6ND but real work:
    dominates small-d_model long-seq cells). Causal halves the square."""
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.n_heads, cfg.head_dim
    L = _n_attn_layers(cfg)
    if cfg.family == "rwkv":
        # wkv recurrence: ~4 flops per (head_dim^2) per token per layer
        per_tok = 4.0 * cfg.d_model * cfg.rwkv_head_dim * cfg.n_layers
        mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[shape.kind]
        toks = B * (S if shape.kind != "decode" else 1)
        return per_tok * toks * mult
    if shape.kind == "train":
        fwd = 2.0 * B * H * S * S * hd * L  # qk+av, causal halved
        extra = 0.0
        if cfg.family == "encdec":
            # enc self (bidir, S/2 each side) + dec cross
            fwd = fwd / 4  # both streams are S//2 long
            Le = cfg.enc_layers
            fwd += 4.0 * B * H * (S // 2) ** 2 * hd * Le / 2
            fwd += 4.0 * B * H * (S // 2) ** 2 * hd * L
        return 3.0 * (fwd + extra)
    if shape.kind == "prefill":
        return 2.0 * B * H * S * S * hd * L
    return 4.0 * B * H * S * hd * L  # decode: 1 token vs S keys


def flash_hbm_traffic(cfg, shape, mesh, flags) -> float:
    """Per-device HBM bytes the Pallas flash kernel actually streams for
    attention (K/V read once per query chunk, Q/O once), replacing the
    CPU-HLO score-tile fusions excluded by the vmem_tile filter.
    Train counts forward + remat-recompute + backward (3 passes)."""
    B, S = shape.global_batch, shape.seq_len
    L = _n_attn_layers(cfg)
    if L == 0 or cfg.family == "rwkv":
        return 0.0
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = axes.get("pod", 1) * axes.get("data", 1)
    tp = axes.get("model", 1)
    B_dev = max(1, B // dp)
    KV, H, hd = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    kv_dev = max(1, KV // tp) if KV % tp == 0 else KV
    h_dev = max(1, H // tp) if H % tp == 0 else H
    if shape.kind == "decode":
        # one-token decode: read the whole (sharded) cache once
        seq_shard = axes.get("data", 1) if (B < dp) else 1
        return (2.0 * B_dev * (S // seq_shard) * kv_dev * hd * 2) * L
    nq = max(1, S // flags.q_chunk)
    kv_bytes = S * kv_dev * hd * 2 * 2          # K+V bf16
    q_o = 2.0 * S * h_dev * hd * 2
    per_layer = nq * kv_bytes + q_o
    passes = 3.0 if shape.kind == "train" else 1.0
    return per_layer * L * B_dev * passes

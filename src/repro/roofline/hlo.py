"""Post-optimization HLO text analysis: FLOPs, memory traffic, and
collective bytes — with while-loop (scan) trip-count weighting, which
XLA's own cost_analysis does NOT do (it counts loop bodies once).

The parser builds a computation call graph, propagates execution weights
(entry=1; while bodies x trip count, parsed from the loop-condition's
comparison constant), then accumulates per-category costs:

  flops            2*M*N*K for every dot (descending into fusions)
  memory bytes     operand+output bytes of top-level instructions in
                   non-fused computations (fusion internals are VMEM/register
                   traffic, not HBM)
  collective bytes per-op operand/output bytes for all-reduce, all-gather,
                   reduce-scatter, all-to-all, collective-permute

This is a static model of the compiled artifact — the only profile available
without hardware — and is validated against analytic 6ND model FLOPs in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape string like 'f32[8,64]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_bytes: int
    operand_bytes: int
    operand_list: List[int]
    flops: float
    called: List[str]
    text: str
    eff_out: float = 0.0          # effective bytes through movement chains
    eff_operands: float = 0.0
    inplace: bool = False         # fusion rooted in dynamic-update-slice


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_fused: bool = False       # called via a fusion instruction
    weight: float = 0.0


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _dot_flops(text: str, symtab: Dict[str, str]) -> float:
    """FLOPs of a dot: 2 * prod(out_dims) * contracted_dims. Operand shapes
    are resolved through the computation's symbol table because
    post-optimization HLO does not inline operand types."""
    m = _SHAPE_RE.search(text)
    if not m:
        return 0.0
    out_elems = 1
    for d in m.group(2).split(","):
        if d:
            out_elems *= int(d)
    cd_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", text)
    # lhs operand: first %name inside the operand parens
    par = text.find("(")
    lhs_dims = None
    if par >= 0:
        nm = _OPERAND_NAME_RE.search(text[par:])
        if nm and nm.group(1) in symtab:
            sm = _SHAPE_RE.search(symtab[nm.group(1)])
            if sm:
                lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    if lhs_dims is None or not cd_m:
        return 2.0 * out_elems  # conservative fallback
    contracted = 1
    for i in cd_m.group(1).split(","):
        if i:
            contracted *= lhs_dims[int(i)]
    return 2.0 * out_elems * contracted


def _operand_list(text: str, symtab: Dict[str, str]) -> List[int]:
    """Byte sizes of each operand, resolved via the symbol table."""
    par = text.find("(")
    if par < 0:
        return []
    depth = 0
    end = par
    for i, ch in enumerate(text[par:], par):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = text[par + 1:end]
    out = []
    for nm in _OPERAND_NAME_RE.finditer(inner):
        shp = symtab.get(nm.group(1))
        if shp is not None:
            out.append(shape_bytes(shp))
    if not out:
        out = [shape_bytes(inner)] if "[" in inner else []
    return out


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    symtab: Dict[str, str] = {}
    pending = []  # (computation, name, opcode, rest) for 2nd pass
    entry: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header: `%name (args...) -> type {`  or `ENTRY %name ...{`
        if stripped.endswith("{") and ("->" in stripped
                                       or stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                symtab = {}
                if stripped.startswith("ENTRY"):
                    entry = cur.name
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        opm = re.search(r"\}?\s*([\w\-]+)\(", rest)
        opcode = opm.group(1) if opm else ""
        called = []
        cm = _CALLED_RE.search(rest)
        if cm:
            called = [c.strip().lstrip("%") for c in cm.group(1).split(",")]
        out_shape = rest.split(" ")[0]
        out_b = shape_bytes(out_shape)
        symtab[name] = out_shape
        fl = _dot_flops(rest, symtab) if opcode == "dot" else 0.0
        ops = _operand_list(rest, symtab)
        cur.instrs.append(Instr(name, opcode, out_b, sum(ops), ops, fl,
                                called, rest))
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop-condition heuristic: largest integer constant compared against
    the induction variable."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" or "constant(" in ins.text:
            for m in re.finditer(r"constant\((\d+)\)", ins.text):
                best = max(best, int(m.group(1)))
    return best


def propagate_weights(comps: Dict[str, Computation]) -> None:
    entry = comps.get("__entry__")
    if entry is None:
        return
    for c in comps.values():
        c.weight = 0.0
    entry.weight = 1.0
    # topological-ish: repeat passes until stable (call graph is a DAG)
    for _ in range(64):
        changed = False
        for c in list(comps.values()):
            if c.weight == 0.0 or c.name == "__entry__":
                pass
            w = c.weight
            if w == 0:
                continue
            for ins in c.instrs:
                if not ins.called:
                    continue
                if ins.opcode == "while":
                    body, cond = None, None
                    bm = re.search(r"body=%?([\w.\-]+)", ins.text)
                    cm = re.search(r"condition=%?([\w.\-]+)", ins.text)
                    if bm and bm.group(1) in comps:
                        body = comps[bm.group(1)]
                    if cm and cm.group(1) in comps:
                        cond = comps[cm.group(1)]
                    trips = _trip_count(cond) if cond else 1
                    if body is not None:
                        nw = w * trips
                        if body.weight < nw:
                            body.weight = nw
                            changed = True
                    if cond is not None and cond.weight < w * (trips + 1):
                        cond.weight = w * (trips + 1)
                        changed = True
                else:
                    if ins.opcode == "fusion":
                        for cn in ins.called:
                            if cn in comps:
                                comps[cn].is_fused = True
                    for cn in ins.called:
                        if cn in comps and comps[cn].weight < w:
                            comps[cn].weight = w
                            changed = True
        if not changed:
            break


@dataclasses.dataclass
class HloCosts:
    flops: float                 # per-device dot FLOPs (trip-weighted)
    memory_bytes: float          # per-device HBM traffic model
    collective_bytes: float      # per-device wire bytes
    collective_counts: Dict[str, int]
    collective_bytes_by_op: Dict[str, float]


_ZERO_TRAFFIC = ("parameter", "constant", "tuple", "get-tuple-element",
                 "while", "conditional", "call", "bitcast", "reshape",
                 "iota", "after-all", "partition-id", "replica-id",
                 "bitcast-convert", "get-dimension-size", "rng-get-and-update-state")

# Pure data-movement ops: CPU lowering materializes these (hoisted converts
# of bf16 caches to f32, layout copies feeding dots, slice extraction from
# scan carries). On the TPU target they fold into the consuming MXU read,
# so they carry *effective bytes* forward instead of generating traffic.
_MOVEMENT = ("convert", "copy", "bitcast", "reshape", "transpose",
             "dynamic-slice", "slice", "broadcast")

_MOVEMENT_ONLY_FUSION = set(_MOVEMENT) | set(_ZERO_TRAFFIC)


def _fusion_is_movement(comp: "Computation") -> bool:
    return all(i.opcode in _MOVEMENT_ONLY_FUSION for i in comp.instrs)


def _make_tile_test(vmem_tile):
    """Match streaming-attention VMEM-resident tiles even after XLA flattens
    the (G, q_chunk) dims: score tiles (.., m*q_chunk, kv_chunk) in both
    orientations, and fp32 flash accumulators (.., m*q_chunk, head_dim) that
    a Pallas kernel keeps on-chip across the KV loop."""
    qc, kc = vmem_tile[:2]
    hd = vmem_tile[2] if len(vmem_tile) > 2 else None

    def test(shape_str: str) -> bool:
        m = _SHAPE_RE.match(shape_str)
        if not m or m.group(1) not in ("f32", "pred", "bf16"):
            return False
        dims = [int(d) for d in m.group(2).split(",") if d]
        if len(dims) < 2:
            return False
        a, b = dims[-2], dims[-1]
        fwd = (b == kc and a >= qc and a % qc == 0)
        bwd = (a == kc and b >= qc and b % qc == 0)  # transposed (backward)
        acc = (m.group(1) == "f32" and len(dims) >= 4 and hd is not None
               and b == hd and a >= qc and a % qc == 0)
        return fwd or bwd or acc

    return test


def resolve_effective(comps: Dict[str, Computation],
                      tile_test=None) -> None:
    dus_comps = {c.name for c in comps.values()
                 if any(i.opcode == "dynamic-update-slice" for i in c.instrs)}
    # scan-carry merge signature: select between the old stacked carry and a
    # fresh slice (XLA-CPU's non-aliased stacking; on TPU the carry update
    # is donated/in-place, so it generates no stack-sized traffic)
    select_merge = {c.name for c in comps.values()
                    if any(i.opcode == "select" for i in c.instrs)
                    and any(i.opcode in ("dynamic-slice",
                                         "dynamic-update-slice")
                            for i in c.instrs)}
    return _resolve_effective(comps, tile_test, dus_comps, select_merge)


def _resolve_effective(comps, tile_test, dus_comps,
                       select_merge=frozenset()) -> None:
    """Effective-bytes propagation: each value's traffic contribution is the
    smallest materialization along its movement chain (e.g. a bf16 cache
    sliced+converted to f32 still costs its bf16 slice), and streaming-
    attention score tiles cost 0 (VMEM-resident in the Pallas kernel on the
    TPU target)."""
    for c in comps.values():
        eff: Dict[str, float] = {}
        symshape: Dict[str, str] = {}
        for ins in c.instrs:
            out_shape = ins.text.split(" ")[0]
            symshape[ins.name] = out_shape
            par = ins.text.find("(")
            op_names = ([m.group(1) for m in
                         _OPERAND_NAME_RE.finditer(ins.text[par:])]
                        if par >= 0 else [])
            op_effs = [eff.get(n, None) for n in op_names]
            op_effs = [ins_bytes for ins_bytes in op_effs
                       if ins_bytes is not None]
            if tile_test is not None and tile_test(out_shape):
                # streaming-attention score tile: VMEM-resident on TPU
                eff[ins.name] = 0.0
                ins.eff_out = 0.0
                ins.eff_operands = float(sum(
                    min(eff.get(n, 0.0), ins.out_bytes) for n in op_names))
                continue
            if ins.opcode in _MOVEMENT:
                src = min(op_effs) if op_effs else ins.out_bytes
                if ins.opcode in ("dynamic-slice", "slice"):
                    e = min(ins.out_bytes, src)
                elif ins.opcode == "broadcast":
                    e = min(op_effs) if op_effs else ins.out_bytes
                else:
                    e = min(ins.out_bytes, src) if op_effs else ins.out_bytes
                eff[ins.name] = e
                ins.eff_out = 0.0        # movement itself is free
                ins.eff_operands = 0.0
            elif (ins.opcode == "fusion" and ins.called and
                  all(cn in comps and _fusion_is_movement(comps[cn])
                      for cn in ins.called)):
                e = min([ins.out_bytes] + op_effs) if op_effs else \
                    ins.out_bytes
                eff[ins.name] = e
                ins.eff_out = 0.0
                ins.eff_operands = 0.0
            else:
                if (ins.opcode == "fusion" and ins.operand_list
                        and max(ins.operand_list) * 2 >= ins.out_bytes
                        and ins.out_bytes >= max(ins.operand_list) // 2
                        and any(cn in select_merge for cn in ins.called)):
                    # in-place scan-carry merge: aliased on TPU; real reads
                    # are charged at the consuming dots
                    eff[ins.name] = ins.out_bytes
                    ins.eff_out = 0.0
                    ins.eff_operands = 0.0
                    continue
                if (ins.opcode == "fusion"
                        and any(cn in dus_comps for cn in ins.called)):
                    # in-place update fusion (cache/accumulator/grad-stack
                    # write): stack-sized operands are aliased or sliced on
                    # TPU; charge only the update-sized traffic
                    ins.inplace = True
                    small = [eff.get(n, 0.0) for n in op_names]
                    upd = sum(b for b in small if b < ins.out_bytes / 2)
                    eff[ins.name] = ins.out_bytes
                    ins.eff_out = float(min(upd, ins.out_bytes))
                    ins.eff_operands = float(min(upd, ins.out_bytes))
                    continue
                eff[ins.name] = ins.out_bytes
                ins.eff_out = float(ins.out_bytes)
                # operand reads at their effective (movement-resolved) size;
                # kLoop fusions read operands through an index map bounded by
                # the output index space — cap each at the output size so a
                # fusion internally slicing a scan carry doesn't charge the
                # whole stacked buffer
                resolved = [eff.get(n, 0.0) for n in op_names]
                if ins.opcode == "fusion":
                    resolved = [min(r, ins.out_bytes) for r in resolved]
                ins.eff_operands = float(sum(resolved))


def _mem_bytes(ins: Instr) -> float:
    """Per-instruction HBM traffic: effective output write + effective
    operand reads, with in-place update-slice aliasing corrected."""
    op = ins.opcode
    if op in _ZERO_TRAFFIC or op in COLLECTIVE_OPS or op in _MOVEMENT:
        return 0.0
    if op == "dynamic-update-slice":
        upd = ins.operand_list[1] if len(ins.operand_list) > 1 else \
            ins.out_bytes
        return 2.0 * upd
    return ins.eff_out + ins.eff_operands


def analyze(text: str, vmem_tile: Optional[Tuple[int, int]] = None
            ) -> HloCosts:
    """vmem_tile: (q_chunk, kv_chunk) — instructions whose output trailing
    dims match the streaming-attention tile are VMEM-resident on the TPU
    target (the Pallas flash kernel keeps them on-chip); exclude them from
    the HBM-traffic model. The dry-run adds the kernel's true HBM traffic
    (streamed K/V per q-chunk) back analytically."""
    comps = parse_hlo(text)
    propagate_weights(comps)
    tile_test = _make_tile_test(vmem_tile) if vmem_tile else None
    resolve_effective(comps, tile_test)
    flops = 0.0
    mem = 0.0
    coll = 0.0
    counts: Dict[str, int] = {}
    coll_by: Dict[str, float] = {}
    comps.pop("__entry__", None)
    for c in comps.values():
        w = c.weight
        if w <= 0:
            continue
        for ins in c.instrs:
            flops += w * ins.flops
            if not c.is_fused:
                mem += w * _mem_bytes(ins)
            if ins.opcode in COLLECTIVE_OPS:
                b = max(ins.out_bytes, ins.operand_bytes)
                coll += w * b
                counts[ins.opcode] = counts.get(ins.opcode, 0) + int(w)
                coll_by[ins.opcode] = coll_by.get(ins.opcode, 0.0) + w * b
    return HloCosts(flops, mem, coll, counts, coll_by)


def top_traffic(text: str, n: int = 25, vmem_tile=None):
    """Diagnostic: heaviest (weight x traffic) instructions."""
    comps = parse_hlo(text)
    propagate_weights(comps)
    tile_test = _make_tile_test(vmem_tile) if vmem_tile else None
    resolve_effective(comps, tile_test)
    comps.pop("__entry__", None)
    rows = []
    for c in comps.values():
        if c.weight <= 0 or c.is_fused:
            continue
        for ins in c.instrs:
            t = c.weight * _mem_bytes(ins)
            if t > 0:
                rows.append((t, c.weight, c.name, ins.opcode,
                             ins.text[:110]))
    rows.sort(reverse=True)
    return rows[:n]

"""Render the dry-run JSONL results into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.roofline.report \
      results/dryrun.jsonl results/dryrun_opt.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, Optional


def load(path: str) -> Dict:
    out = {}
    for line in open(path):
        r = json.loads(line)
        out[(r["arch"], r["shape"], r["multi_pod"])] = r
    return out


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 100:
        return f"{x:.0f}s"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def roofline_table(recs: Dict, baseline: Optional[Dict] = None) -> str:
    lines = [
        "| arch | shape | bottleneck | compute | memory | collective | "
        "step>= | useful | frac | peak/chip | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mp), r in sorted(recs.items()):
        if mp:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | — "
                         f"| — | long_500k skip (full attention) |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR | | | | | | | | "
                         f"{r.get('error', '')[:40]} |")
            continue
        ro = r["roofline"]
        peak = (r["memory"]["peak_bytes"] or 0) / 2 ** 30
        note = ""
        if baseline:
            b = baseline.get((arch, shape, mp))
            if b and b.get("status") == "ok":
                prev = b["roofline"]["step_lower_bound_s"]
                cur = ro["step_lower_bound_s"]
                if prev > 0 and abs(prev / max(cur, 1e-12) - 1) > 0.05:
                    note = f"{prev / cur:.1f}x vs baseline"
        lines.append(
            f"| {arch} | {shape} | {ro['bottleneck']} | "
            f"{fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} | "
            f"{fmt_s(ro['collective_s'])} | "
            f"{fmt_s(ro['step_lower_bound_s'])} | "
            f"{ro['useful_ratio']:.2f} | {ro['roofline_fraction']:.3f} | "
            f"{peak:.0f}GiB | {note} |")
    return "\n".join(lines)


def dryrun_table(recs: Dict) -> str:
    lines = [
        "| arch | shape | mesh | status | compile | args/chip | temp/chip | "
        "collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mp), r in sorted(recs.items(),
                                       key=lambda kv: (kv[0][0], kv[0][1],
                                                       kv[0][2])):
        mesh = "2x16x16" if mp else "16x16"
        if r["status"] != "ok":
            status = r["status"]
            reason = (r.get("reason") or r.get("error", ""))[:50]
            lines.append(f"| {arch} | {shape} | {mesh} | {status} | | | | "
                         f"{reason} |")
            continue
        mem = r["memory"]
        cc = r["hlo"]["collective_counts"]
        cstr = " ".join(f"{k.replace('collective-', 'c-')}:{v}"
                        for k, v in sorted(cc.items()))
        lines.append(
            f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']}s | "
            f"{(mem['argument_bytes'] or 0) / 2**30:.1f}GiB | "
            f"{(mem['temp_bytes'] or 0) / 2**30:.1f}GiB | {cstr[:70]} |")
    return "\n".join(lines)


def main():
    base = load(sys.argv[1]) if len(sys.argv) > 1 else {}
    opt = load(sys.argv[2]) if len(sys.argv) > 2 else base
    print("## Roofline (single pod, optimized; speedups vs baseline sweep)\n")
    print(roofline_table(opt, base))
    print("\n## Dry-run matrix (both meshes)\n")
    print(dryrun_table(opt))


if __name__ == "__main__":
    main()

"""Deterministic synthetic token pipeline with host sharding + prefetch.

Every batch row is a pure function of (seed, step, global row index), so:
(a) restarts reproduce the exact stream with no data-state checkpointing
beyond the step counter, (b) each host generates only its slice
(process_index-based host sharding — on a 1-process runtime that is the
whole batch), and the K-process global batch is bitwise-equal to the
1-process one, (c) a background thread keeps `prefetch` batches ahead of
the training loop.

The token distribution is a mixture of Zipf-like unigram draws and repeated
n-gram motifs so that a small LM's loss actually decreases (pure-uniform
tokens give a flat loss — useless for the convergence tests)."""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frames_dim: Optional[int] = None,
                 embeds_len: int = 0, embeds_dim: Optional[int] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frames_dim = frames_dim
        self.embeds_len = embeds_len
        self.embeds_dim = embeds_dim
        n_proc = jax.process_count()
        assert global_batch % n_proc == 0
        self.host_batch = global_batch // n_proc
        self.host_offset = jax.process_index() * self.host_batch
        # Zipf-ish unigram distribution (shared across rows)
        probs = 1.0 / np.arange(1, vocab + 1)
        self._probs = probs / probs.sum()

    def _row(self, step: int, row: int):
        """One *global* batch row: a pure function of (seed, step, global
        row index) — invariant to process count, so K hosts each stacking
        their own row range reproduce the 1-process batch bitwise."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))
        S, V = self.seq_len, self.vocab
        toks = rng.choice(V, size=(S + 1,), p=self._probs).astype(np.int32)
        # inject a repeated motif (learnable structure)
        motif = rng.integers(0, V, size=(8,), dtype=np.int32)
        for start in range(0, S - 8, max(16, S // 8)):
            toks[start:start + 8] = motif
        frames = embeds = None
        if self.frames_dim:
            frames = rng.standard_normal(
                (S, self.frames_dim)).astype(np.float32) * 0.02
        if self.embeds_len:
            embeds = rng.standard_normal(
                (self.embeds_len, self.embeds_dim)).astype(np.float32) * 0.02
        return toks, frames, embeds

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = [self._row(step, self.host_offset + b)
                for b in range(self.host_batch)]
        toks = np.stack([r[0] for r in rows])
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.frames_dim:
            out["frames"] = np.stack([r[1] for r in rows])
        if self.embeds_len:
            out["embeds"] = np.stack([r[2] for r in rows])
        return out

    def iterator(self, start_step: int = 0, prefetch: int = 2
                 ) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put(self.batch(s))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

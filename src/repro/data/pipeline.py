"""Deterministic synthetic token pipeline with host sharding + prefetch.

Every batch is a pure function of (seed, step), so: (a) restarts reproduce
the exact stream with no data-state checkpointing beyond the step counter,
(b) each host generates only its slice (process_index-based host sharding —
on the 1-process container that is the whole batch), (c) a background
thread keeps `prefetch` batches ahead of the training loop.

The token distribution is a mixture of Zipf-like unigram draws and repeated
n-gram motifs so that a small LM's loss actually decreases (pure-uniform
tokens give a flat loss — useless for the convergence tests)."""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frames_dim: Optional[int] = None,
                 embeds_len: int = 0, embeds_dim: Optional[int] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frames_dim = frames_dim
        self.embeds_len = embeds_len
        self.embeds_dim = embeds_dim
        n_proc = jax.process_count()
        assert global_batch % n_proc == 0
        self.host_batch = global_batch // n_proc
        self.host_offset = jax.process_index() * self.host_batch

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_offset]))
        B, S, V = self.host_batch, self.seq_len, self.vocab
        # Zipf-ish unigrams
        ranks = np.arange(1, V + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(V, size=(B, S + 1), p=probs).astype(np.int32)
        # inject repeated motifs (learnable structure)
        motif = rng.integers(0, V, size=(8,), dtype=np.int32)
        for b in range(B):
            for start in range(0, S - 8, max(16, S // 8)):
                toks[b, start:start + 8] = motif
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.frames_dim:
            out["frames"] = rng.standard_normal(
                (B, S, self.frames_dim)).astype(np.float32) * 0.02
        if self.embeds_len:
            out["embeds"] = rng.standard_normal(
                (B, self.embeds_len, self.embeds_dim)).astype(np.float32) \
                * 0.02
        return out

    def iterator(self, start_step: int = 0, prefetch: int = 2
                 ) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put(self.batch(s))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

"""Mamba-1 selective SSM block (as interleaved in Jamba).

Reference path: `lax.scan` over time (exact). The perf-critical chunked scan
lives in repro.kernels.mamba_scan (Pallas, VMEM-tiled) and is selected with
use_kernel=True.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import common
from repro.layers.common import Accum, Compute
from repro.sharding.rules import constrain


def dims(cfg):
    Di = cfg.mamba_expand * cfg.d_model
    dt_rank = -(-cfg.d_model // 16)
    return Di, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def init(key, cfg):
    D = cfg.d_model
    Di, dt_rank, N, K = dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": common.dense_init(ks[0], D, 2 * Di),
        "conv_w": (jax.random.normal(ks[1], (K, Di), jnp.float32)
                   * (1.0 / K ** 0.5)).astype(Compute),
        "conv_b": jnp.zeros((Di,), Compute),
        "x_proj": common.dense_init(ks[2], Di, dt_rank + 2 * N),
        "dt_proj": common.dense_init(ks[3], dt_rank, Di),
        "dt_bias": jnp.full((Di,), -4.6, Compute),  # softplus ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N)) + 0.0),
        "D_skip": jnp.ones((Di,), jnp.float32),
        "out_proj": common.dense_init(ks[5], Di, D),
    }


def logical_axes(cfg=None):
    return {"in_proj": ("fsdp", "inner"), "conv_w": (None, "inner"),
            "conv_b": ("inner",), "x_proj": ("inner", None),
            "dt_proj": (None, "inner"), "dt_bias": ("inner",),
            "A_log": ("inner", None), "D_skip": ("inner",),
            "out_proj": ("inner", "fsdp")}


def init_state(cfg, batch: int, dtype=Compute):
    Di, _, N, K = dims(cfg)
    return {"conv": jnp.zeros((batch, K - 1, Di), dtype),
            "ssm": jnp.zeros((batch, Di, N), Accum)}


def state_logical():
    return {"conv": ("batch", None, "inner"),
            "ssm": ("batch", "inner", None)}


def _ssm_params(p, x, cfg):
    """x: (B, T, Di) post-conv -> dt (B,T,Di) fp32, Bmat/Cmat (B,T,N)."""
    _, dt_rank, N, _ = dims(cfg)
    proj = x @ p["x_proj"]
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"]).astype(Accum)
                         + p["dt_bias"].astype(Accum))
    return dt, Bm.astype(Accum), Cm.astype(Accum)


def _scan_ref(dt, A, Bm, Cm, x, h0=None):
    """Sequential selective scan. dt,x: (B,T,Di); Bm,Cm: (B,T,N); A: (Di,N).
    Returns y (B,T,Di) fp32 and final state (B,Di,N).

    The discretization exp(dt*A) is computed PER STEP inside the scan — the
    eager (B,T,Di,N) formulation materializes terabytes at production
    shapes (the baseline dry-run exposed this; see EXPERIMENTS.md §Perf).
    The Pallas kernel (kernels/mamba_scan.py) additionally keeps the state
    in VMEM across time chunks."""
    B, T, Di = dt.shape
    N = A.shape[1]

    def step(h, inputs):
        dt_t, x_t, b_t, c_t = inputs                    # (B,Di) (B,Di) (B,N)
        dA_t = jnp.exp(dt_t[..., None] * A)             # (B,Di,N)
        h = dA_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((B, Di, N), Accum)
    hT, ys = jax.lax.scan(step, h0,
                          (dt.transpose(1, 0, 2),
                           x.astype(Accum).transpose(1, 0, 2),
                           Bm.transpose(1, 0, 2),
                           Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), hT


def apply(p, u, cfg, rules=None, mesh=None, state=None, use_kernel=False):
    """u: (B, T, D). If state is given, runs a stateful step (decode: T==1)
    and returns (y, new_state); else returns (y, None)."""
    B, T, D = u.shape
    Di, dt_rank, N, K = dims(cfg)
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                    # (B,T,Di)
    x = constrain(x, ("batch", None, "inner"), rules, mesh)

    new_state = None
    # causal depthwise conv over time; carried history = zero pad for t<0
    carry = state["conv"] if state is not None else jnp.zeros(
        (B, K - 1, Di), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)            # (B, K-1+T, Di)
    new_conv = xp[:, -(K - 1):] if K > 1 else carry
    x = sum(xp[:, i:i + T] * p["conv_w"][i] for i in range(K))
    x = x + p["conv_b"]
    x = jax.nn.silu(x)

    dt, Bm, Cm = _ssm_params(p, x, cfg)
    A = -jnp.exp(p["A_log"])
    if state is None and use_kernel:
        from repro.kernels import ops as kops
        y, hT = kops.mamba_scan(dt, A, Bm, Cm, x)
    else:
        h0 = state["ssm"] if state is not None else None
        y, hT = _scan_ref(dt, A, Bm, Cm, x, h0=h0)
    y = y + x.astype(Accum) * p["D_skip"]
    y = (y.astype(u.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if state is not None:
        new_state = {"conv": new_conv, "ssm": hT}
    return constrain(out, ("batch", None, None), rules, mesh), new_state

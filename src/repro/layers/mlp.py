"""SwiGLU MLP (llama-family) with TP sharding on the hidden dim."""
from __future__ import annotations

import jax

from repro.layers import common
from repro.sharding.rules import constrain


def init(key, cfg, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": common.dense_init(ks[0], D, F),
        "w_up": common.dense_init(ks[1], D, F),
        "w_down": common.dense_init(ks[2], F, D),
    }


def logical_axes(cfg=None):
    return {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"),
            "w_down": ("ff", "fsdp")}


def apply(p, x, cfg, rules=None, mesh=None):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, ("batch", None, "ff"), rules, mesh)
    y = h @ p["w_down"]
    return constrain(y, ("batch", None, None), rules, mesh)

"""Mixture-of-Experts with token-choice top-k routing and expert parallelism.

Dispatch is the TPU-native sort + ragged_dot formulation (exact active-FLOPs,
no dense all-experts waste), run inside shard_map so the expert-parallel
all_to_all over the TP axis is explicit in the HLO — this is the framework
path exercised by the paper's hierarchical alltoall (core.mcoll).

Layout: expert weights (E, D, F) sharded E->tp, D->fsdp (gathered at use,
ZeRO-3 style). Activations are replicated over tp outside this layer; inside,
each tp rank routes a disjoint 1/TP slice of the local tokens, ships them to
expert shards with a fixed per-peer capacity (dropped tokens get zero
combine-weight, standard token-dropping semantics), computes with ragged_dot,
and ships results back.

The dispatch/combine all-to-alls are not hardcoded to one primitive: a full
(algorithm, chunk count) plan is resolved per message size through the TP
**group communicator** — ``communicator(mesh).split(axes=tp)`` — whose
Topology and link metadata are derived from the mesh and whose tuning rows
are namespaced by the group tag (``comm.plan`` — the same selector
``Communicator(algo="auto")`` methods use, so MoE shares the process-wide
tuning table). Large dispatch payloads resolve to the segmented
``pip_pipeline`` all-to-all, which pipelines the exchange in ``chunks``
independent segments. The resolved ``core.mcoll`` algorithm runs inside
the shard_map body. Under a caller ``error_budget`` the combine leg
(expert outputs returning to their tokens) may additionally resolve to an
error-bounded codec plan (``core.compress``) — the optional compressed
combine path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mcoll, runtime
from repro.core.comm import communicator
from repro.layers import common
from repro.layers.common import Accum


def init(key, cfg):
    moe = cfg.moe
    D, E, F = cfg.d_model, moe.n_experts, moe.d_ff_expert
    ks = jax.random.split(key, 4)
    scale = 1.0 / D ** 0.5
    return {
        "router": common.dense_init(ks[0], D, E, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                   * scale).astype(common.Compute),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                 * scale).astype(common.Compute),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   * (1.0 / F ** 0.5)).astype(common.Compute),
    }


def logical_axes(cfg=None):
    return {"router": (None, None),
            "w_gate": ("experts", "fsdp", None),
            "w_up": ("experts", "fsdp", None),
            "w_down": ("experts", None, "fsdp")}


def _route(router, tokens, moe):
    """tokens (t, D) -> (weights (t,k), expert_ids (t,k), probs (t,E))."""
    logits = tokens.astype(Accum) @ router.astype(Accum)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, moe.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalize
    return w, ids, probs


def _expert_compute(x_sorted, group_sizes, wg, wu, wd):
    """ragged grouped matmuls: exact active FLOPs."""
    h = jax.lax.ragged_dot(x_sorted, wg, group_sizes)
    u = jax.lax.ragged_dot(x_sorted, wu, group_sizes)
    h = jax.nn.silu(h) * u
    return jax.lax.ragged_dot(h.astype(x_sorted.dtype), wd, group_sizes)


def _aux_loss(probs, ids, moe):
    """Switch-style load balance loss: E * sum_e f_e * P_e."""
    E = moe.n_experts
    f = jnp.mean(jax.nn.one_hot(ids, E, dtype=Accum).sum(1), axis=0)
    pbar = probs.mean(0)
    return E * jnp.sum(f / moe.top_k * pbar)


def _moe_local(p, tokens, cfg):
    """Single-device reference path (also the oracle for the EP path)."""
    moe = cfg.moe
    t, D = tokens.shape
    w, ids, probs = _route(p["router"], tokens, moe)
    k = moe.top_k
    flat_ids = ids.reshape(-1)
    order = jnp.argsort(flat_ids, stable=True)
    inv = jnp.argsort(order, stable=True)
    x_rep = jnp.repeat(tokens, k, axis=0)[order]
    group_sizes = jnp.bincount(flat_ids, length=moe.n_experts)
    out = _expert_compute(x_rep, group_sizes, p["w_gate"], p["w_up"],
                          p["w_down"])[inv]
    out = out.reshape(t, k, D) * w[..., None].astype(out.dtype)
    return out.sum(1), _aux_loss(probs, ids, moe)


def _ep_capacity(n_tokens: int, tp_size: int, moe) -> int:
    """Per-peer dispatch capacity for `n_tokens` locally routed tokens —
    shared by the shard body and the (outside-shard_map) algorithm
    selection so both see the same message shape."""
    t = -(-n_tokens // tp_size)
    return max(1, int(-(-t * moe.top_k // tp_size) * moe.capacity_factor))


def _moe_ep_shard(p_router, wg, wu, wd, x, cfg, tp_axis, tp_size, a2a_algo,
                  a2a_chunks, comb_algo, comb_chunks, comb_codec, tp_topo):
    """Runs inside shard_map. x: (B_l, S, D) replicated over tp."""
    moe = cfg.moe
    B, S, D = x.shape
    E = moe.n_experts
    E_local = E // tp_size
    k = moe.top_k
    rank = jax.lax.axis_index(tp_axis)
    tokens = x.reshape(-1, D)
    T = tokens.shape[0]
    t = -(-T // tp_size)  # my routing slice (padded)
    pad = t * tp_size - T
    if pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((pad, D), tokens.dtype)], 0)
    mine = jax.lax.dynamic_slice_in_dim(tokens, rank * t, t, axis=0)

    w, ids, probs = _route(p_router, mine, moe)
    flat_ids = ids.reshape(-1)                      # (t*k,)
    flat_w = w.reshape(-1).astype(Accum)
    dest = flat_ids // E_local                      # target tp peer
    cap = _ep_capacity(T, tp_size, moe)
    onehot = jax.nn.one_hot(dest, tp_size, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(t * k), dest]
    valid = pos < cap
    pos_c = jnp.where(valid, pos, cap)              # cap -> dropped
    x_rep = jnp.repeat(mine, k, axis=0)

    send_x = jnp.zeros((tp_size, cap, D), mine.dtype).at[dest, pos_c].set(
        x_rep, mode="drop")
    send_eid = jnp.full((tp_size, cap), E_local - 1, jnp.int32).at[
        dest, pos_c].set(flat_ids % E_local, mode="drop")
    send_ok = jnp.zeros((tp_size, cap), jnp.bool_).at[dest, pos_c].set(
        valid, mode="drop")

    # dispatch/combine exchanges run the selector-resolved mcoll algorithm;
    # large token payloads resolve to the segmented pipeline (chunks > 1),
    # which overlaps one segment's send with the next segment's regroup.
    # The chunk plan is sized for the token payload — the tiny eid/ok
    # metadata exchanges stay unsegmented (chunking them would only add
    # per-collective latency in their latency-bound regime). The combine
    # leg carries its own plan: under a caller error budget it may run
    # compressed (expert outputs tolerate bounded error; dispatched tokens
    # and routing metadata always move lossless).
    fn = mcoll.algorithm("alltoall", a2a_algo)
    a2a_kw = ({"chunks": a2a_chunks}
              if mcoll.supports_chunks("alltoall", a2a_algo) else {})
    a2a = partial(fn, topo=tp_topo, **a2a_kw)
    a2a_meta = partial(fn, topo=tp_topo)
    cfn = mcoll.algorithm("alltoall", comb_algo)
    comb_kw = ({"chunks": comb_chunks}
               if mcoll.supports_chunks("alltoall", comb_algo) else {})
    if comb_codec != "none" and mcoll.supports_codec("alltoall", comb_algo):
        comb_kw["codec"] = comb_codec
    a2a_combine = partial(cfn, topo=tp_topo, **comb_kw)
    rx = a2a(send_x).reshape(tp_size * cap, D)
    re = a2a_meta(send_eid).reshape(tp_size * cap)
    rok = a2a_meta(send_ok).reshape(tp_size * cap)

    eid_eff = jnp.where(rok, re, E_local - 1)
    order = jnp.argsort(eid_eff, stable=True)
    inv = jnp.argsort(order, stable=True)
    group_sizes = jnp.bincount(eid_eff, length=E_local)
    out = _expert_compute(rx[order], group_sizes, wg, wu, wd)[inv]
    out = jnp.where(rok[:, None], out, 0)

    back = a2a_combine(out.reshape(tp_size, cap, D))  # (tp, cap, D) my results
    gathered = back[dest, pos_c]                    # (t*k, D); garbage if !valid
    contrib = gathered * (flat_w * valid)[:, None].astype(gathered.dtype)
    y_mine = contrib.reshape(t, k, D).sum(1)

    y_all = jax.lax.all_gather(y_mine, tp_axis, axis=0, tiled=True)[:T]
    aux = _aux_loss(probs, ids, moe)
    aux_vec = jnp.full((B, S), aux, Accum)
    return y_all.reshape(B, S, D), aux_vec


def apply(p, x, cfg, rules=None, mesh=None, error_budget: float = 0.0):
    """x: (B, S, D). Returns (y, aux_loss_per_token (B,S)).

    ``error_budget`` opts the **combine** all-to-all (expert outputs coming
    back) into error-bounded compression: the selector may pick any codec
    whose stated bound fits the budget (``core.compress``), shrinking the
    return leg's wire bytes. Dispatch and routing metadata always move
    lossless — token values feed expert matmuls and indices must be exact.
    """
    B, S, D = x.shape
    tp = rules.tp if rules else None
    tp_size = mesh.shape[tp] if (mesh is not None and tp in
                                 getattr(mesh, "axis_names", ())) else 1
    if mesh is None or tp_size == 1 or cfg.moe.n_experts % tp_size != 0:
        y, aux = _moe_local(p, x.reshape(-1, D), cfg)
        return y.reshape(B, S, D), jnp.full((B, S), aux, Accum)

    batch_axes = tuple(a for a in (rules.batch or ()) if a in mesh.axis_names)

    # resolve the dispatch/combine algorithm through the TP group
    # communicator for the actual per-device exchange size
    # (tp_size x capacity x D): split(axes=tp) derives the group Topology
    # (link classes from the mesh) and namespaces its tuning rows under the
    # "tp" group tag; the memoized root shares the process-wide selector,
    # so MoE rides the same table as every other consumer
    bshard = 1
    for a in batch_axes:
        bshard *= mesh.shape[a]
    cap = _ep_capacity(-(-B // bshard) * S, tp_size, cfg.moe)
    comm = communicator(mesh).split(axes=tp)
    tp_topo = comm.topo
    nbytes = tp_size * cap * D * x.dtype.itemsize
    a2a_sel = comm.plan("alltoall", nbytes, dtype=str(x.dtype))
    comb_sel = (comm.plan("alltoall", nbytes, dtype=str(x.dtype),
                          error_budget=error_budget)
                if error_budget > 0.0 else a2a_sel)

    xspec = P(batch_axes if batch_axes else None, None, None)
    fn = runtime.sharded(
        partial(_moe_ep_shard, cfg=cfg, tp_axis=tp, tp_size=tp_size,
                a2a_algo=a2a_sel.algo, a2a_chunks=a2a_sel.chunks,
                comb_algo=comb_sel.algo, comb_chunks=comb_sel.chunks,
                comb_codec=comb_sel.codec, tp_topo=tp_topo),
        mesh,
        in_specs=(P(None, None), P(tp, None, None), P(tp, None, None),
                  P(tp, None, None), xspec),
        out_specs=(xspec, P(batch_axes if batch_axes else None, None)),
        check=False)
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)

"""RWKV-6 (Finch) block: time mixing with data-dependent decay + channel
mixing. Attention-free; O(1) state per token makes long_500k decode cheap.

Reference recurrence via lax.scan; the chunked Pallas kernel lives in
repro.kernels.rwkv6_wkv."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import common
from repro.layers.common import Accum, Compute
from repro.sharding.rules import constrain

DECAY_LORA = 64


def n_heads(cfg):
    assert cfg.d_model % cfg.rwkv_head_dim == 0
    return cfg.d_model // cfg.rwkv_head_dim


def init(key, cfg):
    D = cfg.d_model
    H, hd = n_heads(cfg), cfg.rwkv_head_dim
    F = cfg.d_ff
    ks = jax.random.split(key, 12)
    return {
        "tm": {
            # token-shift interpolation weights for r/k/v/w/g
            "mu": (0.5 * jnp.ones((5, D), jnp.float32)).astype(Compute),
            "wr": common.dense_init(ks[0], D, D),
            "wk": common.dense_init(ks[1], D, D),
            "wv": common.dense_init(ks[2], D, D),
            "wg": common.dense_init(ks[3], D, D),
            "wo": common.dense_init(ks[4], D, D),
            # data-dependent decay (the defining v6 feature):
            # w_t = exp(-exp(w0 + tanh(x_w @ w1) @ w2))
            "w0": jnp.full((D,), -2.0, jnp.float32),
            "w1": common.dense_init(ks[5], D, DECAY_LORA, dtype=jnp.float32),
            "w2": common.dense_init(ks[6], DECAY_LORA, D, dtype=jnp.float32),
            "u": (jax.random.normal(ks[7], (H, hd), jnp.float32)
                  * 0.1),
            "ln_x": {"scale": jnp.ones((D,), Compute)},
        },
        "cm": {
            "mu": (0.5 * jnp.ones((2, D), jnp.float32)).astype(Compute),
            "wk": common.dense_init(ks[8], D, F),
            "wv": common.dense_init(ks[9], F, D),
            "wr": common.dense_init(ks[10], D, D),
        },
    }


def logical_axes(cfg=None):
    return {
        "tm": {"mu": (None, None), "wr": ("fsdp", "heads"),
               "wk": ("fsdp", "heads"), "wv": ("fsdp", "heads"),
               "wg": ("fsdp", "heads"), "wo": ("heads", "fsdp"),
               "w0": (None,), "w1": (None, None), "w2": (None, None),
               "u": ("heads", None), "ln_x": {"scale": (None,)}},
        "cm": {"mu": (None, None), "wk": ("fsdp", "ff"),
               "wv": ("ff", "fsdp"), "wr": ("fsdp", None)},
    }


def init_state(cfg, batch: int, dtype=Compute):
    D = cfg.d_model
    H, hd = n_heads(cfg), cfg.rwkv_head_dim
    return {"tm_shift": jnp.zeros((batch, D), dtype),
            "wkv": jnp.zeros((batch, H, hd, hd), Accum),
            "cm_shift": jnp.zeros((batch, D), dtype)}


def state_logical():
    return {"tm_shift": ("batch", None), "wkv": ("batch", "heads", None, None),
            "cm_shift": ("batch", None)}


def _shift(x, carry):
    """Token shift: x_{t-1} with carry for t=0. x: (B,T,D), carry: (B,D)."""
    return jnp.concatenate([carry[:, None], x[:, :-1]], axis=1)


def wkv6_ref(r, k, v, w, u, s0):
    """WKV6 recurrence. r,k,v,w: (B,T,H,hd) (w already in (0,1) decay form,
    fp32); u: (H,hd); s0: (B,H,hd,hd) initial state.
    y_t = r_t . (S_{t-1} + u * k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns y (B,T,H,hd) fp32, final state."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y
    seq = (r.transpose(1, 0, 2, 3).astype(Accum),
           k.transpose(1, 0, 2, 3).astype(Accum),
           v.transpose(1, 0, 2, 3).astype(Accum),
           w.transpose(1, 0, 2, 3))
    sT, ys = jax.lax.scan(step, s0, seq)
    return ys.transpose(1, 0, 2, 3), sT


def time_mix(p, x, cfg, state_shift=None, state_wkv=None, rules=None,
             mesh=None, use_kernel=False):
    B, T, D = x.shape
    H, hd = n_heads(cfg), cfg.rwkv_head_dim
    carry = state_shift if state_shift is not None else jnp.zeros((B, D),
                                                                  x.dtype)
    xprev = _shift(x, carry)
    mu = p["mu"]
    xr, xk, xv, xw, xg = (x + (xprev - x) * mu[i] for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, hd)
    k = (xk @ p["wk"]).reshape(B, T, H, hd)
    v = (xv @ p["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay
    dd = (p["w0"] + jnp.tanh(xw.astype(Accum) @ p["w1"]) @ p["w2"])
    w = jnp.exp(-jnp.exp(dd)).reshape(B, T, H, hd)     # in (0,1)
    s0 = state_wkv if state_wkv is not None else jnp.zeros((B, H, hd, hd),
                                                           Accum)
    if use_kernel and state_wkv is None:
        from repro.kernels import ops as kops
        y, sT = kops.rwkv6_wkv(r, k, v, w, p["u"], s0)
    else:
        y, sT = wkv6_ref(r, k, v, w, p["u"], s0)
    y = y.reshape(B, T, D).astype(x.dtype)
    y = common.rmsnorm(y, p["ln_x"]["scale"], cfg.norm_eps) * g
    out = y @ p["wo"]
    out = constrain(out, ("batch", None, None), rules, mesh)
    return out, x[:, -1], sT


def channel_mix(p, x, cfg, state_shift=None):
    B, T, D = x.shape
    carry = state_shift if state_shift is not None else jnp.zeros((B, D),
                                                                  x.dtype)
    xprev = _shift(x, carry)
    mu = p["mu"]
    xk = x + (xprev - x) * mu[0]
    xr = x + (xprev - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    kv = k @ p["wv"]
    out = jax.nn.sigmoid(xr @ p["wr"]) * kv
    return out, x[:, -1]

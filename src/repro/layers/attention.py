"""Grouped-query attention with RoPE/M-RoPE, KV caching, cross-attention,
and a flash-decode path for long contexts (Pallas kernel, see
repro.kernels.flash_decode).

Sharding: heads over TP when divisible; KV cache sequence dim over the
context-parallel axis for long_500k (GSPMD inserts the partial-softmax
collectives automatically under pjit)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers import common
from repro.layers.common import Accum, Compute
from repro.sharding.rules import constrain


def init(key, cfg, cross: bool = False):
    D = cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hp = cfg.padded_heads
    ks = jax.random.split(key, 4)
    wq = common.dense_init(ks[0], D, Hp * hd)
    wo = common.dense_init(ks[3], Hp * hd, D, scale=1.0 / (Hp * hd) ** 0.5)
    if Hp != H:
        # TP head padding: heads are laid out (kv-major, group-minor), so
        # the pad heads must sit at the TAIL OF EACH KV GROUP to preserve
        # the true q->kv mapping. Zero wq columns + wo rows there, so padded
        # heads contribute exactly nothing.
        G_true, G_pad = H // KV, Hp // KV
        g_of = (jnp.arange(Hp * hd) // hd) % G_pad
        mask = (g_of < G_true)
        wq = wq * mask[None, :].astype(wq.dtype)
        wo = wo * mask[:, None].astype(wo.dtype)
    p = {
        "wq": wq,
        "wk": common.dense_init(ks[1], D, KV * hd),
        "wv": common.dense_init(ks[2], D, KV * hd),
        "wo": wo,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((Hp * hd,), Compute)
        p["bk"] = jnp.zeros((KV * hd,), Compute)
        p["bv"] = jnp.zeros((KV * hd,), Compute)
    return p


def logical_axes(cfg, cross: bool = False):
    la = {"wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
          "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp")}
    if cfg.qkv_bias and not cross:
        la.update({"bq": ("heads",), "bk": ("kv_heads",),
                   "bv": ("kv_heads",)})
    return la


def init_cache(cfg, batch: int, max_len: int, dtype=Compute):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((batch, max_len, KV, hd), dtype),
    }


def cache_logical():
    return {"k": ("batch", "seq", "kv_heads", None),
            "v": ("batch", "seq", "kv_heads", None)}


def cache_pspec(cfg, rules, mesh_shape):
    """PartitionSpec for the KV cache.

    kv_heads shard over TP when divisible; otherwise the cache SEQUENCE dim
    takes the TP axis (context-parallel decode: each rank attends to its
    window and GSPMD combines the partial softmaxes with tiny psums) —
    replication of a 32k cache or per-layer re-gather is never acceptable.
    rules.seq (data-axis context parallelism for long_500k) composes on the
    same dim."""
    from jax.sharding import PartitionSpec as P
    batch = tuple(a for a in (rules.batch or ())
                  if mesh_shape.get(a, 1) > 1) or None
    seq_axes = []
    if rules.seq and mesh_shape.get(rules.seq, 1) > 1:
        seq_axes.append(rules.seq)
    kv_ax = None
    tp = rules.tp
    if tp and mesh_shape.get(tp, 1) > 1:
        if cfg.n_kv_heads % mesh_shape[tp] == 0:
            kv_ax = tp
        else:
            seq_axes.append(tp)
    return P(batch, tuple(seq_axes) or None, kv_ax, None)


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _gqa_scores(q, k):
    """q: (B,T,H,hd), k: (B,S,KV,hd) -> (B,KV,G,T,S) fp32.

    bf16 operands with fp32 accumulation (preferred_element_type) — never
    materialize an fp32 copy of the KV cache (XLA would hoist the convert
    out of the decode loop: +2x HBM)."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    return jnp.einsum("btkgd,bskd->bkgts", qg, k,
                      preferred_element_type=Accum) / (hd ** 0.5)


def _gqa_out(w, v):
    """w: (B,KV,G,T,S) fp32 probs, v: (B,S,KV,hd) -> (B,T,H*hd) fp32."""
    B, KV, G, T, S = w.shape
    hd = v.shape[-1]
    o = jnp.einsum("bkgts,bskd->btkgd", w.astype(v.dtype), v,
                   preferred_element_type=Accum)
    return o.reshape(B, T, KV * G * hd)


def attend_full(q, k, v, causal: bool, q_offset=0):
    """Full-materialization attention — reference for short sequences and
    the oracle for the streaming/Pallas paths. fp32 softmax."""
    s = _gqa_scores(q, k)
    T, S = s.shape[-2], s.shape[-1]
    if causal:
        qpos = jnp.arange(T)[:, None] + q_offset
        kpos = jnp.arange(S)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_out(w, v)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def attend_streaming(q, k, v, causal: bool, q_chunk: int = 512,
                     kv_chunk: int = 1024, q_offset=0):
    """Online-softmax (flash) attention in pure JAX: tiles over query and KV
    chunks so the score matrix never materializes — forward streams tiles,
    and the custom VJP implements the Dao backward (recompute p from the
    saved log-sum-exp; only q/k/v/out/lse are saved, no tile stacks).

    q: (B,T,H,hd); k,v: (B,S,KV,hd). Chunk sizes are hillclimb levers."""
    out, _ = _streaming_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset)
    return out


def _streaming_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    B, T, H, hd = q.shape
    S = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, T)
    kv_chunk = min(kv_chunk, S)
    if T % q_chunk or S % kv_chunk:
        return attend_full(q, k, v, causal, q_offset), None
    nq, nk = T // q_chunk, S // kv_chunk
    qg = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd)
    scale = 1.0 / (hd ** 0.5)

    def q_block(qi_and_q):
        qi, qb = qi_and_q                     # qb: (B,qc,KV,G,hd)
        m0 = jnp.full((B, KV, G, q_chunk), -jnp.inf, Accum)
        l0 = jnp.zeros((B, KV, G, q_chunk), Accum)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), Accum)

        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kb, vb = ki_and_kv
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=Accum) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s = jnp.where(kpos <= qpos, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                preferred_element_type=Accum)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kc.transpose(1, 0, 2, 3, 4),
             vc.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,KV,G,qc,hd)
        lse = jnp.where(jnp.isfinite(m), m, 0.0) + jnp.log(
            jnp.maximum(l, 1e-30))                     # (B,KV,G,qc)
        return out, lse

    outs, lses = jax.lax.map(q_block, (jnp.arange(nq),
                                       qg.transpose(1, 0, 2, 3, 4, 5)))
    # outs: (nq, B, KV, G, qc, hd) -> (B, T, H*hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, T, H * hd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, T)
    return out, lse


def _streaming_fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    out, lse = _streaming_fwd_impl(q, k, v, causal, q_chunk, kv_chunk,
                                   q_offset)
    return out, (q, k, v, out, lse)


def _streaming_bwd(causal, q_chunk, kv_chunk, q_offset, res, dout):
    """Flash backward (Dao): recompute p tiles from the saved lse; only
    O(q/k/v) accumulators live — no score-tile stacks."""
    q, k, v, out, lse = res
    B, T, H, hd = q.shape
    S = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    if lse is None:  # fell back to attend_full (small seq): use plain VJP
        _, vjp = jax.vjp(lambda q_, k_, v_: attend_full(q_, k_, v_, causal,
                                                        q_offset), q, k, v)
        return vjp(dout)
    qc = min(q_chunk, T)
    kc = min(kv_chunk, S)
    nq, nk = T // qc, S // kc
    scale = 1.0 / (hd ** 0.5)
    do = dout.reshape(B, T, KV, G, hd)
    og = out.reshape(B, T, KV, G, hd)
    # delta[t] = sum_d do*out  (B,KV,G,T)
    delta = jnp.einsum("btkgd,btkgd->bkgt", do.astype(Accum),
                       og.astype(Accum))
    qg = q.reshape(B, nq, qc, KV, G, hd)
    dog = do.reshape(B, nq, qc, KV, G, hd)
    lse_g = lse.reshape(B, KV, G, nq, qc)
    delta_g = delta.reshape(B, KV, G, nq, qc)
    kcs = k.reshape(B, nk, kc, KV, hd)
    vcs = v.reshape(B, nk, kc, KV, hd)

    def kv_block(dq_acc, ki_kb_vb):
        """Outer scan over KV chunks: carry the q-sized dq accumulator, emit
        this chunk's (dk, dv)."""
        ki, kb, vb = ki_kb_vb                  # (B,kc,KV,hd)
        dk0 = jnp.zeros((B, kc, KV, hd), Accum)
        dv0 = jnp.zeros((B, kc, KV, hd), Accum)

        def q_step(carry, qi):
            dk, dv = carry
            qb = qg[:, qi]                     # (B,qc,KV,G,hd)
            dob = dog[:, qi]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=Accum) * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)[:, None] + q_offset
                kpos = ki * kc + jnp.arange(kc)[None, :]
                s = jnp.where(kpos <= qpos, s, -jnp.inf)
            p = jnp.exp(s - lse_g[:, :, :, qi][..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)     # (B,KV,G,qc,kc)
            pb = p.astype(vb.dtype)
            dv = dv + jnp.einsum("bkgqs,bqkgd->bskd", pb, dob,
                                 preferred_element_type=Accum)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb,
                            preferred_element_type=Accum)
            ds = p * (dp - delta_g[:, :, :, qi][..., None]) * scale
            dsb = ds.astype(kb.dtype)
            dq_c = jnp.einsum("bkgqs,bskd->bqkgd", dsb, kb,
                              preferred_element_type=Accum)
            dk = dk + jnp.einsum("bkgqs,bqkgd->bskd", dsb, qb,
                                 preferred_element_type=Accum)
            return (dk, dv), dq_c

        (dk, dv), dq_chunks = jax.lax.scan(q_step, (dk0, dv0),
                                           jnp.arange(nq))
        # dq_chunks: (nq,B,qc,KV,G,hd) -> add into the full-T accumulator
        dq_acc = dq_acc + dq_chunks.transpose(1, 0, 2, 3, 4, 5).reshape(
            B, T, KV, G, hd)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, T, KV, G, hd), Accum)
    dq, (dks, dvs) = jax.lax.scan(
        kv_block, dq0,
        (jnp.arange(nk), kcs.transpose(1, 0, 2, 3, 4),
         vcs.transpose(1, 0, 2, 3, 4)))
    dq = dq.reshape(B, T, H, hd)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


attend_streaming.defvjp(_streaming_fwd, _streaming_bwd)


def attend_decode(q, cache_k, cache_v, cur_index, use_kernel: bool = False):
    """One-token decode against a (possibly sharded) KV cache.

    q: (B,1,H,hd); cache: (B,S,KV,hd); cur_index: count of valid positions
    (the new token is already written at cur_index-1) — a scalar, or a
    ``(B,)`` vector of per-row counts (continuous batching: each slot at
    its own true length). Rows mask independently, so a freshly admitted
    short row never attends past its own filled positions."""
    if use_kernel and not getattr(cur_index, "ndim", 0):
        from repro.kernels import ops as kops
        return kops.flash_decode(q, cache_k, cache_v, cur_index)
    s = _gqa_scores(q, cache_k)  # (B,KV,G,1,S)
    S = s.shape[-1]
    if getattr(cur_index, "ndim", 0):
        valid = (jnp.arange(S)[None, None, None, None, :]
                 < cur_index[:, None, None, None, None])
    else:
        valid = jnp.arange(S)[None, None, None, None, :] < cur_index
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_out(w, cache_v)


STREAMING_THRESHOLD = 2048  # T*S above (threshold^2) switches to streaming


def apply(p, x, cfg, *, rules=None, mesh=None, mode: str = "causal",
          positions=None, positions3=None, cache=None, cache_index=None,
          kv_source=None, use_flash_decode: bool = False,
          q_chunk: int = 512, kv_chunk: int = 1024):
    """Modes: "causal" (train/prefill decoder), "bidir" (encoder),
    "cross" (enc-dec cross-attn; kv_source = encoder output),
    "decode" (single step; cache + cache_index required).

    Returns (y, new_cache). new_cache is None unless mode=="decode" or
    mode=="causal" with cache provided (prefill fill-in)."""
    B, T, D = x.shape
    H, KV, hd = cfg.padded_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    kv_in = kv_source if mode == "cross" else x
    k = kv_in @ p["wk"]
    v = kv_in @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _split_heads(q, H, hd)
    k = _split_heads(k, KV, hd)
    v = _split_heads(v, KV, hd)

    if cfg.rope != "none" and mode != "cross":
        if positions is None:
            base = cache_index if mode == "decode" else 0
            if getattr(base, "ndim", 0):
                # per-row decode indices: each slot's rotary position is its
                # own true length (mixed-length continuous batching)
                positions = jnp.arange(T)[None, :] + base[:, None]
            else:
                positions = jnp.arange(T)[None, :] + base
                positions = jnp.broadcast_to(positions, (B, T))
        if cfg.rope == "mrope":
            p3 = positions3 if positions3 is not None else \
                common.text_positions3(positions)
            sections = cfg.head_dim // 2 // 4, cfg.head_dim // 2 * 3 // 8, \
                cfg.head_dim // 2 * 3 // 8
            cos, sin = common.mrope_cos_sin(p3, hd, cfg.rope_theta, sections)
        else:
            cos, sin = common.rope_cos_sin(positions, hd, cfg.rope_theta)
        q = common.apply_rope(q, cos, sin)
        k = common.apply_rope(k, cos, sin)

    q = constrain(q, ("batch", None, "heads", None), rules, mesh)
    k = constrain(k, ("batch", None, "kv_heads", None), rules, mesh)

    new_cache = None
    if mode == "decode":
        assert cache is not None and cache_index is not None
        if getattr(cache_index, "ndim", 0):
            # per-row write offsets: slot b's new KV lands at its own true
            # length, not the batch max (which would leave uninitialized
            # rows a short sequence then attends over)
            row_upd = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))
            ck = row_upd(cache["k"], k, cache_index)
            cv = row_upd(cache["v"], v, cache_index)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k,
                                              (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v,
                                              (0, cache_index, 0, 0))
        if mesh is not None and rules is not None:
            from jax.sharding import NamedSharding
            spec = cache_pspec(cfg, rules,
                               dict(zip(mesh.axis_names, mesh.devices.shape)))
            ck = jax.lax.with_sharding_constraint(
                ck, NamedSharding(mesh, spec))
            cv = jax.lax.with_sharding_constraint(
                cv, NamedSharding(mesh, spec))
        new_cache = {"k": ck, "v": cv}
        o = attend_decode(q, ck, cv, cache_index + 1,
                          use_kernel=use_flash_decode)
    else:
        if cache is not None and mode == "causal":  # prefill: fill cache
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
        if q.shape[1] * k.shape[1] > STREAMING_THRESHOLD ** 2:
            o = attend_streaming(q, k, v, causal=(mode == "causal"),
                                 q_chunk=q_chunk, kv_chunk=kv_chunk)
        else:
            o = attend_full(q, k, v, causal=(mode == "causal"))
    o = o.astype(x.dtype)
    y = o @ p["wo"]
    return constrain(y, ("batch", None, None), rules, mesh), new_cache

"""Shared building blocks: initializers, norms, rotary embeddings, embedding
tables with TP-friendly vocab padding."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Compute = jnp.bfloat16
Accum = jnp.float32


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None,
               dtype=Compute):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-5):
    h = x.astype(Accum)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def init_rmsnorm(d: int, dtype=Compute):
    return {"scale": jnp.ones((d,), dtype)}


def pad_vocab(vocab: int, multiple: int) -> int:
    """Pad the vocab so the embedding/logits dims shard over TP cleanly."""
    return -(-vocab // multiple) * multiple


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=Accum)
                            / head_dim))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (..., T) int -> cos/sin (..., T, head_dim//2)."""
    ang = positions[..., None].astype(Accum) * rope_freqs(head_dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, T, H, hd); cos/sin: (B, T, hd//2) (broadcast over heads)."""
    x1, x2 = jnp.split(x.astype(Accum), 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def mrope_cos_sin(positions3, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE: three position streams (temporal, height, width)
    fill disjoint frequency sections. positions3: (B, 3, T).
    Returns cos/sin (B, T, head_dim//2)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(head_dim, theta)  # (half,)
    ang_all = positions3[..., None].astype(Accum) * freqs  # (B, 3, T, half)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)  # (half,)
    # frequency slot f uses the position stream sections[f] belongs to
    ang = jnp.moveaxis(ang_all, 1, -1)  # (B, T, half, 3)
    ang = jnp.take_along_axis(ang, sec_id[None, None, :, None],
                              axis=-1)[..., 0]
    return jnp.cos(ang), jnp.sin(ang)


def text_positions3(positions):
    """Text-only M-RoPE degenerates to three equal streams."""
    return jnp.stack([positions] * 3, axis=1)

"""AdamW + gradient clipping + LR schedules, from scratch (pytree-native).

Optimizer state is fp32 (m, v); params may be bf16 (master copies in fp32
optional via `master_fp32`). The DP gradient sync pairs with the
error-bounded compressed-collective subsystem (``repro.core.compress``
codecs + ``train.manual_step``'s per-bucket ``error_budget``) for
wire-compressed exchange with error feedback.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    master_fp32: bool = False
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        else:
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - t)
    return cfg.lr * warm * decay


def init(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32),
                                       params)
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


_NO_DECAY_SUBSTR = ("ln", "norm", "bias", "scale", "mu", "A_log", "D_skip",
                    "dt_bias", "w0", "u")


def _decay_mask(params):
    def mask_path(path, _):
        names = [getattr(k, "key", str(k)) for k in path]
        joined = "/".join(str(n) for n in names).lower()
        return not any(s in joined for s in _NO_DECAY_SUBSTR)
    return jax.tree_util.tree_map_with_path(mask_path, params)


def update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    decay_mask = _decay_mask(params)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)

    base = state.get("master", params)

    def upd(p, m, v, dm):
        p32 = p.astype(jnp.float32)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p32 * dm
        return p32 - lr * u

    new_base = jax.tree.map(upd, base, new_m, new_v, decay_mask)
    new_params = jax.tree.map(lambda nb, p: nb.astype(p.dtype), new_base,
                              params)
    new_state = {"step": step, "m": new_m, "v": new_v}
    if "master" in state:
        new_state["master"] = new_base
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_logical(param_logical, cfg: AdamWConfig):
    """Optimizer state shards exactly like the params (ZeRO semantics)."""
    out = {"step": (), "m": param_logical, "v": param_logical}
    if cfg.master_fp32:
        out["master"] = param_logical
    return out

"""Int8 block-quantized gradient compression with error feedback.

Distributed-optimization trick for the DP gradient sync path: gradients are
quantized to int8 with per-block fp32 scales before crossing the slow
(DCN/pod) axis, and the quantization error is fed back into the next step's
gradient (error feedback preserves convergence, Karimireddy et al. 2019).

Wire ratio ~3.7x vs bf16 (int8 payload + one fp32 scale per 256 elements).
Used by train.manual_step's mcoll allreduce variant; unit-tested for
round-trip error bounds and error-feedback convergence in
tests/test_optim.py.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x):
    """x: float array -> (int8 blocks, fp32 per-block scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = -n % BLOCK
    padded = jnp.pad(flat, (0, pad))
    blocks = padded.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)),
                 -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q, scale, shape):
    blocks = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, error_state):
    """Quantize every leaf after adding carried error feedback.

    Returns ((qs, scales) list-trees aligned with grads, new_error_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error_state)
    qs: List = []
    scales: List = []
    new_err: List = []
    for g, e in zip(leaves, err_leaves):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        back = dequantize(q, s, g.shape)
        qs.append(q)
        scales.append(s)
        new_err.append(corrected - back)
    return (qs, scales, treedef), jax.tree.unflatten(treedef, new_err)


def decompress_tree(compressed, shapes_like):
    qs, scales, treedef = compressed
    shape_leaves = [l.shape for l in jax.tree.leaves(shapes_like)]
    out = [dequantize(q, s, shp)
           for q, s, shp in zip(qs, scales, shape_leaves)]
    return jax.tree.unflatten(treedef, out)


def wire_bytes(compressed) -> int:
    qs, scales, _ = compressed
    return sum(q.size for q in qs) + sum(s.size * 4 for s in scales)


# ---------------------------------------------------------------------------
# int8-on-the-wire allreduce (runs inside shard_map)
# ---------------------------------------------------------------------------


def compressed_allreduce(x, topo):
    """Allreduce keeping int8 payloads on the wire in BOTH phases:
    (1) all-to-all the quantized slices (reduce-scatter pattern),
    (2) local dequant + sum + requant,
    (3) all-gather the reduced int8 slices.

    ~3.7x wire reduction vs bf16 at <0.8% per-block quantization error.
    Must run inside shard_map over topo.axes; x: (n,) fp32 per device."""
    import jax
    from jax import lax

    W = topo.world
    n = x.shape[0]
    padded = -(-n // (W * BLOCK)) * (W * BLOCK)
    xp = jnp.pad(x.astype(jnp.float32), (0, padded - n))
    slices = xp.reshape(W, padded // W)
    q, s = quantize(slices.reshape(-1))           # blocks of all slices
    qs = q.reshape(W, -1, BLOCK)                  # (W, blocks/slice, BLOCK)
    ss = s.reshape(W, -1)
    # phase 1: slice i of every peer -> device i   (int8 + fp32 scales)
    rq = lax.all_to_all(qs, topo.axes, split_axis=0, concat_axis=0,
                        tiled=False)              # (W, blocks/slice, BLOCK)
    rs = lax.all_to_all(ss, topo.axes, split_axis=0, concat_axis=0,
                        tiled=False)
    # phase 2: dequant + sum over sources, requant
    deq = rq.astype(jnp.float32) * rs[..., None]  # (W, blk, BLOCK)
    mine = deq.sum(axis=0).reshape(-1)            # my reduced slice
    q2, s2 = quantize(mine)
    # phase 3: all-gather reduced slices (int8 + scales)
    gq = lax.all_gather(q2, topo.axes, axis=0, tiled=False)
    gs = lax.all_gather(s2, topo.axes, axis=0, tiled=False)
    full = (gq.astype(jnp.float32) * gs[..., None]).reshape(-1)
    return full[:n]

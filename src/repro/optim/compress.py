"""Int8 block-quantized gradient compression — compatibility re-export.

The codec math lives in :mod:`repro.core.compress` (the codec registry of
the error-bounded compressed-collective subsystem); this module re-exports
the original tree-level API so optimizer-side callers keep importing from
``repro.optim.compress``. No quantize/dequantize implementation lives here.

The bespoke ``compressed_allreduce`` that used to live in this module is
superseded by the subsystem's compressed execution: call
``Communicator.allreduce(x, algo="pip_mcoll", codec="int8_block")``
(``repro.core.comm``; or ``algo="auto"`` with an ``error_budget``), which
shares the compiled-callable cache and the selection subsystem with every
other consumer. Error feedback is threaded through ``err=`` on the
``core.mcoll`` compressed allreduce.
"""
from repro.core.compress import (  # noqa: F401
    BLOCK,
    compress_tree,
    decompress_tree,
    dequantize,
    init_error_state,
    quantize,
    wire_bytes,
)

__all__ = ["BLOCK", "quantize", "dequantize", "init_error_state",
           "compress_tree", "decompress_tree", "wire_bytes"]

"""Checkpointing + fault tolerance.

- Atomic directory commits (write to .tmp, fsync, rename) so a crash
  mid-save never corrupts the latest checkpoint.
- Async saves on a background thread (training never blocks on disk).
- Elastic restore: arrays are re-sharded onto whatever mesh/shardings the
  restoring job provides (device_put with target shardings), so a job can
  come back on a different topology — the elastic-scaling path.
- Keyed flat layout: one .npy per leaf keyed by its pytree path, plus a
  JSON manifest (step, leaf paths, dtypes) — no pickle, fully portable.

Failure-injection tests (tests/test_checkpoint.py) kill a training run
mid-stream and assert bitwise-identical continuation after restore.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


_SAFE = re.compile(r"[^\w\-/.]")


def _fname(path_str: str) -> str:
    return _SAFE.sub("_", path_str).replace("/", "__") + ".npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        host_leaves = [(_path_str(p), np.asarray(v)) for p, v in leaves]
        if blocking:
            self._write(step, host_leaves)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves) -> None:
        tmp = self.dir / f".tmp_step_{step:09d}"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for path_str, arr in host_leaves:
            fn = _fname(path_str)
            logical_dtype = str(arr.dtype)
            raw_view = arr.dtype.kind == "V" or logical_dtype not in (
                "float64", "float32", "float16", "int64", "int32", "int16",
                "int8", "uint64", "uint32", "uint16", "uint8", "bool")
            if raw_view:
                # bf16/fp8 etc.: store as a raw same-width uint view
                arr = arr.view(f"u{arr.dtype.itemsize}")
            np.save(tmp / fn, arr)
            manifest["leaves"].append(
                {"path": path_str, "file": fn, "dtype": logical_dtype,
                 "raw_view": bool(raw_view), "shape": list(arr.shape)})
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like`; optionally re-shard onto
        target `shardings` (same pytree structure) — the elastic path."""
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {e["path"]: e for e in manifest["leaves"]}

        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for (path, leaf), shd in zip(leaves, shard_leaves):
            ps = _path_str(path)
            if ps not in by_path:
                raise KeyError(f"checkpoint missing leaf {ps}")
            entry = by_path[ps]
            arr = np.load(d / entry["file"])
            if entry.get("raw_view"):
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
            target_dtype = getattr(leaf, "dtype", arr.dtype)
            if str(arr.dtype) != str(target_dtype):
                arr = arr.astype(target_dtype)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out)

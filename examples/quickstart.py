"""Quickstart: train a reduced Llama-family model for 100 steps on CPU,
checkpoint, and resume — the smallest end-to-end path through the stack.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.launch.train import main as train_main

with tempfile.TemporaryDirectory() as d:
    print("== phase 1: train 60 steps, checkpointing ==")
    train_main(["--arch", "smollm-360m", "--reduced", "--steps", "60",
                "--batch", "4", "--seq", "64", "--ckpt-dir", d,
                "--ckpt-every", "25"])
    print("== phase 2: resume from latest checkpoint, train to 100 ==")
    train_main(["--arch", "smollm-360m", "--reduced", "--steps", "100",
                "--batch", "4", "--seq", "64", "--ckpt-dir", d,
                "--ckpt-every", "25"])
print("quickstart OK")

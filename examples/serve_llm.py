"""Serve a reduced model with continuous batching: 12 requests with varied
prompt lengths stream through an 4-slot engine.

  PYTHONPATH=src python examples/serve_llm.py
"""
import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import decoder
from repro.serve.engine import Engine, Request

cfg = reduced_config("qwen1.5-4b")
params = decoder.init(jax.random.PRNGKey(0), cfg)
engine = Engine(params, cfg, max_batch=4, max_len=96)

rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(int(n),),
                                    dtype=np.int32),
                max_new_tokens=8)
        for n in rng.integers(4, 24, size=12)]
done = engine.run(reqs)
for i, r in enumerate(done):
    print(f"req{i:02d} prompt_len={len(r.prompt):3d} -> {r.out_tokens}")
assert len(done) == len(reqs) and all(len(r.out_tokens) >= 8 for r in done)
print("serve_llm OK")

"""PiP-MColl in action: run every collective algorithm on a simulated
(4 nodes x 2 locals) cluster, verify identical results, and print the cost
model's predicted latency on the paper's cluster vs TPU v5e.

  PYTHONPATH=src python examples/collectives_demo.py
(This example forces 8 host devices; run it standalone, not from a session
that already initialized jax.)
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune, compress, costmodel, mcoll
from repro.core.comm import Communicator
from repro.core.topology import Topology

N, P = 4, 2
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)
comm = Communicator(mesh, topo)
x = jnp.arange(N * P * 4, dtype=jnp.float32)

print(f"== allgather on {N}x{P} devices (Communicator API, cached) ==")
for algo in mcoll.algorithms("allgather"):
    out = np.asarray(comm.allgather(x, algo=algo, stacked=True))
    ok = all((out[d] == np.asarray(x)).all() for d in range(N * P))
    print(f"  {algo:20s} correct={ok}")
    assert ok
    comm.allgather(x, algo=algo, stacked=True)
stats = comm.cache_stats()
print(f"  runtime cache: {stats.exec_hits} hits / "
      f"{stats.exec_misses} compiles")

print(f"\n== persistent nonblocking allreduce (init once, start/wait) ==")
zp = (jnp.arange(N * P * 16, dtype=jnp.float32) % 9).reshape(N * P, 16)
blocking = np.asarray(comm.allreduce(zp, algo="pip_mcoll"))
op = comm.allreduce_init(zp, algo="pip_mcoll", depth=2)
misses0 = comm.cache_stats().exec_misses
h1 = op.start(zp)            # returns immediately (async dispatch)
h2 = op.start(zp)            # double-buffered: 2nd start before 1st wait
outs = [np.asarray(h1.wait()), np.asarray(h2.wait())]
for o in outs:
    np.testing.assert_array_equal(o, blocking)
assert comm.cache_stats().exec_misses == misses0, "start must not compile"
print(f"  plan={op.plan} starts={op.starts} "
      f"compiles_after_init=0 bitwise==blocking=True")

print("\n== modeled small-message latency, paper cluster (128x18) ==")
big = Topology(128, 18)
for m in (64, 256, 1024):
    pip = costmodel.allgather_cost("pip_mcoll", big, m,
                                   costmodel.paper_cluster_pip())
    rd = costmodel.allgather_cost("recursive_doubling", big, m,
                                  costmodel.paper_cluster_cma())
    print(f"  {m:5d}B  pip_mcoll {pip.us():9.1f}us  "
          f"({pip.inter_rounds} inter rounds)   flat-RD {rd.us():9.1f}us "
          f"({rd.inter_rounds} rounds)  speedup {rd.time / pip.time:.1f}x")

print("\n== modeled on TPU v5e pod (16 x 16 chips, hierarchical axes) ==")
pod = Topology(16, 16)
for m in (256, 4096, 1 << 20):
    pip = costmodel.allgather_cost("pip_mcoll", pod, m,
                                   costmodel.tpu_v5e_pod())
    sl = costmodel.allgather_cost("single_leader", pod, m,
                                  costmodel.tpu_v5e_pod())
    print(f"  {m:8d}B  pip_mcoll {pip.us():9.1f}us  single-leader "
          f"{sl.us():9.1f}us  speedup {sl.time / pip.time:.2f}x")

print("\n== chunked pipelining: pip_pipeline allreduce (runtime, chunks=) ==")
z = (jnp.arange(N * P * 12, dtype=jnp.float32) % 13).reshape(N * P, 12)
expect = np.asarray(z).sum(0)
for c in (1, 2, 4):
    out = np.asarray(comm.allreduce(z, algo="pip_pipeline", chunks=c))
    assert all((out[d] == expect).all() for d in range(N * P))
    print(f"  chunks={c} correct=True")
net = costmodel.tpu_v5e_pod()
for m in (4096, 1 << 20, 1 << 24):
    c = costmodel.optimal_chunks("allreduce", "pip_pipeline", pod, m, net)
    t1 = costmodel.allreduce_cost("pip_pipeline", pod, m, net, chunks=1)
    tc = costmodel.allreduce_cost("pip_pipeline", pod, m, net, chunks=c)
    print(f"  modeled {m:8d}B  c*={c:3d}  unchunked {t1.us():9.1f}us  "
          f"chunked {tc.us():9.1f}us  win {t1.time / tc.time:.2f}x")
xo = costmodel.pipeline_crossover_bytes("allreduce", "pip_pipeline", pod, net)
print(f"  modeled pipelining crossover: {xo}B")

print("\n== error-bounded compressed collectives (codec=) ==")
zr = (jax.random.normal(jax.random.PRNGKey(0), (N * P, 2048)) * 0.01)
exact = np.asarray(zr).sum(0)
A = float(np.abs(np.asarray(zr)).max())
for cd in compress.lossy():
    out = np.asarray(comm.allreduce(zr, algo="pip_mcoll", codec=cd))
    err = np.abs(out[0] - exact).max()
    tol = compress.collective_tolerance(cd, "allreduce", N * P, A)
    assert err <= tol + 1e-7, (cd, err, tol)
    m = compress.meta(cd)
    print(f"  {cd:11s} ratio={m.wire_ratio:4.1f}x stated_bound="
          f"{m.error_bound:.4f}  achieved_err={err:.2e} (tol {tol:.2e})")

print("\n== codec selection under an error budget (16x16 DCN pod) ==")
dcn = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
sel = autotune.Selector()
print(f"  {'size':>10s}  " + "  ".join(f"budget={b:<7g}"
                                       for b in (0.0, 0.004, 0.07, 1.0)))
for size in (256, 65536, 1 << 20, 1 << 24):
    plans = []
    for b in (0.0, 0.004, 0.07, 1.0):
        s = sel.choose("allreduce", dcn, size, error_budget=b)
        plans.append(autotune.encode_plan(s.algo, s.chunks, s.codec))
    print(f"  {size:>9d}B  " + "  ".join(f"{p:<14s}" for p in plans))
zero = sel.choose("allreduce", dcn, 1 << 24, error_budget=0.0)
assert zero.codec == "none", "error_budget=0.0 must stay lossless"
print("collectives_demo OK")

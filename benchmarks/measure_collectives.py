"""Wall-clock the real shard_map collective implementations on 8 CPU host
devices (launched by benchmarks/run.py with XLA_FLAGS set). CPU collective
timing does not model ICI, but the ROUND-COUNT ordering (pip_mcoll fewer
rounds than flat algorithms) shows up in dispatch overhead, and correctness
of every algorithm is asserted on the way.

All invocations go through repro.core.runtime's compiled-callable cache:
the first call per (collective, algo, shape) key compiles, every timed call
is a cache hit, so re-trace/re-jit overhead is excluded from the measured
numbers. Hit/miss totals are emitted as a measured/ row for run.py.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcoll, runtime
from repro.core.topology import Topology

N, P = 4, 2
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)


def bench(fn, x, n=20):
    out = jax.block_until_ready(fn(x))  # compile (exec-cache miss)
    t0 = time.time()
    for _ in range(n):                  # timed calls are all cache hits
        out = jax.block_until_ready(fn(x))
    return (time.time() - t0) / n * 1e6, out


for nbytes in (256, 65536):
    m = nbytes // 4 // (N * P)
    x = jnp.arange(N * P * max(m, 1), dtype=jnp.float32)
    for algo in mcoll.algorithms("allgather"):
        fn = lambda a, _algo=algo: runtime.collective(
            mesh, topo, "allgather", _algo, a, stacked=True)
        us, out = bench(fn, x)
        ok = bool((np.asarray(out)[0] == np.asarray(x)).all())
        assert ok, algo
        print(f"measured/allgather/{algo}/{nbytes}B,{us:.1f},8cpu-dev ok")
    for algo in mcoll.algorithms("allreduce"):
        z = jnp.ones((N * P, max(m, 1)), jnp.float32)
        fn = lambda a, _algo=algo: runtime.collective(
            mesh, topo, "allreduce", _algo, a)
        us, out = bench(fn, z)
        print(f"measured/allreduce/{algo}/{nbytes}B,{us:.1f},8cpu-dev ok")

stats = runtime.cache_stats()
assert stats.exec_hits > 0 and stats.exec_misses > 0, stats
print(f"measured/runtime_cache,0.0,exec_hits={stats.exec_hits} "
      f"exec_misses={stats.exec_misses} "
      f"hit_rate={stats.exec_hit_rate:.3f}")

"""Wall-clock the real shard_map collective implementations on 8 CPU host
devices (launched by benchmarks/run.py with XLA_FLAGS set). CPU collective
timing does not model ICI, but the ROUND-COUNT ordering (pip_mcoll fewer
rounds than flat algorithms) shows up in dispatch overhead, and correctness
of every algorithm is asserted on the way.

All invocations go through the Communicator API (repro.core.comm) backed
by the runtime's compiled-callable cache: the first call per (collective,
algo, shape) key compiles, every timed call is a cache hit, so
re-trace/re-jit overhead is excluded from the measured numbers. Hit/miss
totals are emitted as a measured/ row for run.py.

Modes:
  (default)             measured rows for allgather/allreduce, every
                        explicit algorithm plus algo="auto" (result
                        asserted identical to the explicit runs), a chunk
                        sweep of the pipelined allreduce, and compressed
                        rows per codec (wall-clock + achieved error vs the
                        codec's stated bound).
  --calibrate OUT.json  run comm.calibrate over all six collectives
                        (chunked and codec plans included), persist the
                        tuning table + latency rows + a model-vs-measured
                        crossover comparison + the pipeline-crossover
                        table + a compression section (achieved ratio /
                        error, crossover vs lossless) as JSON
                        (the BENCH_collectives artifact).
  --overlap [OUT.json]  persistent-op overlap leg: barrier-style vs
                        overlapped bucketed allreduce (one persistent op,
                        depth=1 start/wait pairs vs depth=K windowed
                        starts), the init-vs-start amortization curve, and
                        the four-leg **train-step** matrix ({monolithic,
                        backward-segmented} x {barrier, overlapped}) with
                        paired-difference deltas and the >=8-device
                        non-regression gate. With OUT.json, merges an
                        "overlap" section into the artifact
                        (results/BENCH_collectives.json).
  --codec-kernels [OUT.json]
                        codec-kernel microbench: fused Pallas codec
                        lowerings vs the jnp reference path per fused
                        codec (wall-clock both jitted, analytic HBM
                        traffic per stage, roofline seconds at HBM_BW),
                        asserting the fused encode pass moves <= half the
                        jnp path's bytes; with OUT.json, merges a
                        "codec_kernels" section into the artifact and
                        writes results/BENCH_codec_kernels.json.

The mesh factors the ambient device count into (node, local) — run.py
forces 8 host devices (4x2); the CI conformance matrix runs the overlap
leg at {1, 2, 8}.

Under the multi-process launcher (``python -m repro.distributed.launch
--processes K --devices M -- benchmarks/measure_collectives.py
--calibrate OUT``) the mesh is ``(K processes, M devices)`` with the node
axis on the process boundary (host_ipc inter / host_cpu intra links); only
``--calibrate`` is supported there — every rank runs the SPMD sweeps,
rank 0 merges the tables and writes one artifact stamped
``backend="multiprocess"`` / ``process_count=K``.
"""
import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (artifact as artifact_schema, autotune, compress,
                        costmodel, mcoll, runtime, telemetry)
from repro.core.comm import Communicator
from repro.core.topology import Topology
from repro.distributed import backend as dist_backend
from repro.launch.mesh import make_process_mesh

# must run before the first device query: under the repro.distributed
# launcher this joins the multi-controller runtime (no-op otherwise)
BACKEND = dist_backend.auto_initialize()

DC = jax.device_count()
if BACKEND.multiprocess:
    # node axis == process boundary, so derive_link splits host_ipc (inter)
    # from host_cpu (intra) — the hierarchy the calibration is measuring
    mesh = make_process_mesh()
    N, P = mesh.devices.shape
else:
    P = 2 if DC % 2 == 0 else 1
    N = DC // P
    mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology.from_mesh(mesh)
comm = Communicator(mesh, topo)

# cross-process gloo runs are far slower per dispatch than in-process host
# devices; trim the sweep so the multiprocess calibrate leg stays tractable
CAL_SIZES = (256, 4096) if BACKEND.multiprocess else (256, 4096, 65536)
CAL_ITERS = 3 if BACKEND.multiprocess else 10


def bench(fn, x, n=20):
    out = jax.block_until_ready(fn(x))  # compile (exec-cache miss)
    t0 = time.time()
    for _ in range(n):                  # timed calls are all cache hits
        out = jax.block_until_ready(fn(x))
    return (time.time() - t0) / n * 1e6, out


def measure_mode():
    for nbytes in (256, 65536):
        m = nbytes // 4 // (N * P)
        x = jnp.arange(N * P * max(m, 1), dtype=jnp.float32)
        ag_out = None
        for algo in mcoll.algorithms("allgather"):
            if algo not in autotune.candidates("allgather", topo):
                continue
            fn = lambda a, _algo=algo: comm.allgather(a, algo=_algo,
                                                      stacked=True)
            us, out = bench(fn, x)
            ok = bool((np.asarray(out)[0] == np.asarray(x)).all())
            assert ok, algo
            ag_out = np.asarray(out)
            print(f"measured/allgather/{algo}/{nbytes}B,{us:.1f},8cpu-dev ok")
        # algo="auto": resolved through the selector, result must match
        resolved, _ = runtime.resolve_algo(topo, "allgather", "auto", x)
        fn = lambda a: comm.allgather(a, stacked=True)
        us, out = bench(fn, x)
        np.testing.assert_array_equal(np.asarray(out), ag_out)
        print(f"measured/allgather/auto/{nbytes}B,{us:.1f},"
              f"resolved={resolved}")
        for algo in mcoll.algorithms("allreduce"):
            if algo not in autotune.candidates("allreduce", topo):
                continue
            z = jnp.ones((N * P, max(m, 1)), jnp.float32)
            fn = lambda a, _algo=algo: comm.allreduce(a, algo=_algo)
            us, out = bench(fn, z)
            print(f"measured/allreduce/{algo}/{nbytes}B,{us:.1f},8cpu-dev ok")
        z = jnp.ones((N * P, max(m, 1)), jnp.float32)
        resolved, _ = runtime.resolve_algo(topo, "allreduce", "auto", z)
        us, out = bench(lambda a: comm.allreduce(a), z)
        np.testing.assert_allclose(np.asarray(out)[0],
                                   np.full(max(m, 1), N * P, np.float32))
        print(f"measured/allreduce/auto/{nbytes}B,{us:.1f},"
              f"resolved={resolved}")

    # pipelined allreduce chunk sweep at the largest size: wall-clock per
    # chunk count, results asserted identical to chunks=1
    m = 65536 // 4 // (N * P)
    z = jnp.ones((N * P, m), jnp.float32)
    base = None
    for c in (1, 2, 4, 8):
        us, out = bench(lambda a, _c=c: comm.allreduce(
            a, algo="pip_pipeline", chunks=_c), z)
        if base is None:
            base = np.asarray(out)
        else:
            np.testing.assert_allclose(np.asarray(out), base, rtol=1e-6)
        print(f"measured/allreduce/pip_pipeline_c{c}/65536B,{us:.1f},"
              f"8cpu-dev ok")

    # compressed allreduce per codec at the largest size: wall-clock +
    # achieved relative error vs the exact sum (the accuracy side of the
    # wire-ratio trade, asserted against the codec's stated bound)
    zr = (jax.random.normal(jax.random.PRNGKey(0), (N * P, m)) * 0.01)
    exact = np.asarray(zr).sum(0)
    A = float(np.abs(np.asarray(zr)).max())
    denom = np.abs(exact).max() + 1e-12
    for cd in compress.lossy():
        us, out = bench(lambda a, _cd=cd: comm.allreduce(
            a, algo="pip_mcoll", codec=_cd), zr)
        err = float(np.abs(np.asarray(out)[0] - exact).max())
        tol = compress.collective_tolerance(cd, "allreduce", N * P, A)
        assert err <= tol + 1e-7, (cd, err, tol)
        print(f"measured/allreduce/pip_mcoll@{cd}/65536B,{us:.1f},"
              f"rel_err={err / denom:.5f} "
              f"ratio={compress.meta(cd).wire_ratio:.2f}x")

    stats = runtime.cache_stats()
    assert stats.exec_hits > 0 and stats.exec_misses > 0, stats
    print(f"measured/runtime_cache,0.0,exec_hits={stats.exec_hits} "
          f"exec_misses={stats.exec_misses} "
          f"hit_rate={stats.exec_hit_rate:.3f}")
    sstats = runtime.selection_stats()
    print(f"measured/selection,0.0,prior={sstats.prior} "
          f"measured={sstats.measured}")


def calibrate_mode(out_path: str):
    sel = comm.selector
    # multiprocess trims codec plans too (the compression section below
    # still measures every lossy codec end to end on the same mesh)
    rows = comm.calibrate(sizes=CAL_SIZES, iters=CAL_ITERS,
                          codecs=(() if BACKEND.multiprocess else None))
    for r in rows:
        plan = autotune.encode_plan(r.algo, r.chunks, r.codec)
        print(f"calibrate/{r.collective}/{plan}/{r.nbytes}B,"
              f"{r.seconds * 1e6:.1f},measured")
    # model-vs-measured: where does the measured winner disagree with the
    # cost-model prior on this mesh?
    prior_sel = autotune.Selector()  # empty table -> prior only
    comparison = []
    agree = 0
    for name in runtime.collectives():
        for nbytes in CAL_SIZES:
            measured = sel.choose(name, topo, nbytes)
            prior = prior_sel.choose(name, topo, nbytes)
            match = measured.algo == prior.algo
            agree += match
            # per-plan signed relative error (measured - model) / model:
            # every measured plan at this (collective, size), not just the
            # crossover verdict — the drift detector's offline counterpart
            per_plan = []
            entry = sel.table.lookup(topo, name, "float32", nbytes) or {}
            for plan_key in sorted(entry):
                meas_s = entry[plan_key]
                model_s = autotune.predicted_seconds(name, plan_key, topo,
                                                     nbytes)
                per_plan.append({
                    "plan": plan_key,
                    "measured_us": meas_s * 1e6,
                    "model_us": (model_s * 1e6
                                 if model_s and model_s > 0.0 else None),
                    "signed_rel_err": ((meas_s - model_s) / model_s
                                       if model_s and model_s > 0.0
                                       else None),
                })
            comparison.append({
                "collective": name, "nbytes": nbytes,
                "measured_algo": measured.algo,
                "measured_us": measured.seconds * 1e6,
                "prior_algo": prior.algo,
                "prior_us": prior.seconds * 1e6,
                "agree": match,
                "per_plan": per_plan,
            })
            print(f"calibrate/crossover/{name}/{nbytes}B,0.0,"
                  f"measured={measured.algo} prior={prior.algo} "
                  f"agree={match}")
    total = len(comparison)
    print(f"calibrate/model_vs_measured,0.0,agree={agree}/{total}")
    # pipeline crossover: per pipelined pair, modeled unchunked vs
    # optimally-chunked latency across a size sweep (where does chunking
    # start to win?) plus the measured per-plan medians at the calibrated
    # sizes, so the artifact shows model and measurement side by side
    net = costmodel.net_for(topo)
    pipeline_rows = []
    for coll in runtime.collectives():
        for algo in sorted(mcoll.CHUNKED[coll]):
            fn = costmodel.COST_FNS[coll]
            xover = costmodel.pipeline_crossover_bytes(coll, algo, topo, net)
            model_sweep = []
            for nbytes in (256, 4096, 65536, 1 << 20, 1 << 24):
                c = costmodel.optimal_chunks(coll, algo, topo, nbytes, net)
                model_sweep.append({
                    "nbytes": nbytes, "chunks": c,
                    "unchunked_us": fn(algo, topo, nbytes, net,
                                       chunks=1).time * 1e6,
                    "chunked_us": fn(algo, topo, nbytes, net,
                                     chunks=c).time * 1e6,
                })
            measured = {}
            for nbytes in CAL_SIZES:
                entry = sel.table.lookup(topo, coll, "float32", nbytes) or {}
                plans = {k: v * 1e6 for k, v in entry.items()
                         if autotune.decode_plan(k)[0] == algo}
                if plans:
                    measured[str(nbytes)] = plans
            pipeline_rows.append({
                "collective": coll, "algo": algo,
                "model_crossover_bytes": xover,
                "model_sweep": model_sweep,
                "measured_us_by_plan": measured,
            })
            print(f"calibrate/pipeline/{coll}/{algo},0.0,"
                  f"model_crossover={xover}")
    # compression: per codec — declared + achieved wire ratio, achieved
    # error on a measured compressed allreduce (vs its stated bound), the
    # same-algo modeled crossover vs lossless, and the budget-selection
    # crossover (smallest size where auto under that codec's budget goes
    # lossy on this topology)
    compression_rows = []
    m = 65536 // 4 // (N * P)
    zr = (jax.random.normal(jax.random.PRNGKey(0), (N * P, m)) * 0.01)
    exact = np.asarray(zr).sum(0)
    A = float(np.abs(np.asarray(zr)).max())
    sweep_sizes = tuple(2 ** i for i in range(6, 25))
    for cd in compress.lossy():
        c = compress.codec(cd)
        sample = jax.random.normal(jax.random.PRNGKey(1), (1, m))
        achieved_ratio = 4.0 * m / c.wire_bytes(c.encode(sample))
        out = comm.allreduce(zr, algo="pip_mcoll", codec=cd)
        err = float(np.abs(dist_backend.to_host(out)[0] - exact).max())
        bound_abs = compress.collective_tolerance(cd, "allreduce", N * P, A)
        xover_model = costmodel.compressed_crossover_bytes(
            "allreduce", "pip_pipeline", topo, net, cd, sizes=sweep_sizes)
        budget = c.meta.error_bound
        prior_only = autotune.Selector()
        xover_budget = next(
            (s for s in sweep_sizes
             if prior_only.choose("allreduce", topo, s,
                                  error_budget=budget).codec != "none"),
            None)
        compression_rows.append({
            "codec": cd,
            "declared_ratio": c.meta.wire_ratio,
            "achieved_ratio": achieved_ratio,
            "stated_rel_bound": c.meta.error_bound,
            "achieved_abs_error": err,
            "bound_abs_tolerance": bound_abs,
            "model_crossover_vs_lossless_bytes": xover_model,
            "budget_selection_crossover_bytes": xover_budget,
        })
        print(f"calibrate/compression/{cd},0.0,"
              f"ratio={achieved_ratio:.2f}x err={err:.2e} "
              f"bound={bound_abs:.2e} model_crossover={xover_model} "
              f"budget_crossover={xover_budget}")
    artifact = dist_backend.stamp_artifact({
        "topology": autotune.topo_key(topo),
        "sizes": list(CAL_SIZES),
        "table": sel.table.to_json(),
        "latency_rows": [r.__dict__ for r in rows],
        "model_vs_measured": comparison,
        "pipeline_crossover": pipeline_rows,
        "compression": compression_rows,
    })
    # refuse to write a malformed artifact: every section + row key this
    # mode is responsible for must be present (schema in core.artifact)
    artifact_schema.validate(artifact,
                             sections=artifact_schema.CALIBRATE_SECTIONS)
    # comm.calibrate() already folded every rank's rows into rank 0's
    # table, so rank 0 writes the single merged artifact
    if BACKEND.process_index == 0:
        path = pathlib.Path(out_path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(artifact, indent=1, sort_keys=True))
        print(f"calibrate/artifact,0.0,{path}")
    dist_backend.barrier("calibrate_mode/done")


def overlap_mode(out_path=None):
    """Persistent-op overlap leg (the Communicator API's headline claim).

    Three measurements, all deterministic-plan:
      1. bucketed allreduce microbench — one persistent op over a stream of
         K equal buckets: barrier-style (depth=1, wait each start before
         the next) vs overlapped (depth=K, start the whole window then
         wait), i.e. MPI_Start/Wait pairing vs software pipelining;
      2. init-vs-start amortization — one-time plan+compile cost vs the
         per-start cost it buys, amortized over n starts;
      3. train-step delta — four make_overlapped_train_step legs on the
         reduced config: {monolithic, backward-segmented} x {barrier,
         overlapped}, timed in interleaved rounds so paired per-round
         differences cancel drift. The monolithic pair isolates allreduce
         *dispatch* pipelining (one backward program, sync after); the
         segmented pair overlaps bucket i's allreduce with bucket i+1's
         backward *compute*. Twins of one decomposition are bit-identical
         by construction (asserted). delta_ms = the segmented-overlapped
         step vs the monolithic barrier baseline (the end-to-end win); at
         >= 8 devices the leg asserts delta_ms >= 0 and delta_ms >
         dispatch-only overlap (the CI gate).
    """
    M = N * P
    n = (256 << 10) // 4  # 256 KiB per bucket
    K = 8
    algo = "pip_pipeline"
    reps = 5
    buckets = [(jnp.arange(M * n, dtype=jnp.float32) % 7 + b).reshape(M, n)
               for b in range(K)]

    op_b = comm.allreduce_init(shape=(M, n), dtype=jnp.float32, algo=algo,
                               depth=1)
    op_o = comm.allreduce_init(shape=(M, n), dtype=jnp.float32, algo=algo,
                               depth=K)
    # warm both paths (shared compiled executable; asserted identical)
    ref = np.asarray(op_b.start(buckets[0]).wait())
    np.testing.assert_array_equal(
        np.asarray(op_o.start(buckets[0]).wait()), ref)

    def barrier_pass():
        outs = []
        for b in buckets:
            outs.append(op_b.start(b).wait(block=True))
        return outs

    def overlapped_pass():
        handles = [op_o.start(b) for b in buckets]
        outs = [h.wait(block=False) for h in handles]
        jax.block_until_ready(outs)
        return outs

    barrier_pass(), overlapped_pass()  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        ob = barrier_pass()
    barrier_us = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        oo = overlapped_pass()
    overlapped_us = (time.perf_counter() - t0) / reps * 1e6
    for a, b in zip(ob, oo):  # bit-identical across scheduling styles
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    speedup = barrier_us / max(overlapped_us, 1e-9)
    print(f"overlap/microbench/barrier/{K}x{n * 4}B,{barrier_us:.1f},"
          f"plan={op_b.plan}")
    print(f"overlap/microbench/overlapped/{K}x{n * 4}B,{overlapped_us:.1f},"
          f"speedup={speedup:.2f}x")

    # init-vs-start amortization: persistent init pays plan resolution +
    # compile once; a start is a bare dispatch. A fresh shape forces a true
    # cold init (exec-cache miss).
    n2 = n + 16
    xc = jnp.ones((M, n2), jnp.float32)
    t0 = time.perf_counter()
    op_c = comm.allreduce_init(shape=(M, n2), dtype=jnp.float32, algo=algo)
    init_us = (time.perf_counter() - t0) * 1e6
    op_c.start(xc).wait()  # first dispatch warms the executable
    samples = []
    for _ in range(20):
        t0 = time.perf_counter()
        op_c.start(xc).wait(block=True)
        samples.append(time.perf_counter() - t0)
    start_us = float(np.median(samples)) * 1e6
    amortization = [
        {"starts": k, "amortized_us_per_start": (init_us + k * start_us) / k}
        for k in (1, 2, 4, 8, 16, 32, 64)]
    print(f"overlap/amortization,0.0,init_us={init_us:.1f} "
          f"start_us={start_us:.1f} "
          f"breakeven_starts={max(1, int(init_us / max(start_us, 1e-9)))}")

    # train-step leg: barrier vs overlapped bucketed gradient sync on the
    # reduced config (identical compiled programs, scheduling differs)
    from repro.configs import reduced_config
    from repro.models import decoder
    from repro.models.decoder import RunFlags
    from repro.optim import adamw
    from repro.train import manual_step
    from repro.train.step import TrainConfig

    cfg = reduced_config("smollm-360m")
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                             schedule="constant", grad_clip=1e9)
    tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat="none"))
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (max(M, 2), 32), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(1),
                                          (max(M, 2), 32), 0, cfg.vocab)}
    # four legs, two decompositions x two schedules:
    #   mono_barrier / mono_overlap — ONE backward program emitting every
    #     bucket, so overlap=True can only pipeline allreduce *dispatch*
    #     (the PR-5 measurement; its headline number);
    #   seg_barrier / segmented — backward-segmented decomposition, where
    #     bucket i's allreduce is in flight while bucket i+1's backward
    #     segment COMPUTES.
    # Twins of one decomposition run identical compiled programs (only host
    # scheduling differs) -> their trained params must be bit-identical.
    legs = (("mono_barrier", False, False), ("mono_overlap", True, False),
            ("seg_barrier", False, True), ("segmented", True, True))
    states, n_buckets, n_segments = {}, {}, 0
    for label, ov, seg in legs:
        params = decoder.init(key, cfg)
        opt = adamw.init(params, ocfg)
        step = manual_step.make_overlapped_train_step(
            cfg, tcfg, mesh, topo, algo=algo, bucket_bytes=256 << 10,
            overlap=ov, segmented=seg)
        # two warm steps: the first compiles, the second settles the
        # donated-param shardings (a step whose apply re-lays-out params
        # triggers one more compile of the consumers on the NEXT call —
        # that must not land in the timed window)
        for _ in range(2):
            params, opt, m = step(params, opt, batch)
            jax.block_until_ready((params, m["loss"]))
        states[label] = [step, params, opt]
        n_buckets[label] = len(step.grad_sync.slices)
        if seg:
            n_segments = len(step.bounds)
    # interleaved rounds: one timed step per leg per round, so slow drift
    # (CPU frequency, co-tenants) hits every leg alike and the PAIRED
    # per-round differences cancel it — the gated metrics are medians of
    # those paired differences, not differences of medians
    reps_t = 10
    samples = {label: [] for label, _, _ in legs}
    for _ in range(reps_t):
        for label, _, _ in legs:
            slot = states[label]
            step_l, params, opt = slot
            t0 = time.perf_counter()
            params, opt, m = step_l(params, opt, batch)
            jax.block_until_ready((params, m["loss"]))
            samples[label].append((time.perf_counter() - t0) * 1e3)
            slot[1], slot[2] = params, opt
    step_times = {k: float(np.median(v)) for k, v in samples.items()}
    for label, _, _ in legs:
        print(f"overlap/train_step/{label},"
              f"{step_times[label] * 1e3:.1f},"
              f"buckets={n_buckets[label]}")
    for a, b in (("mono_barrier", "mono_overlap"),
                 ("seg_barrier", "segmented")):
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda x, y: float(jnp.abs(x.astype(jnp.float32)
                                       - y.astype(jnp.float32)).max()),
            states[a][1], states[b][1])))
        assert diff == 0.0, f"{a} vs {b} twins diverged: {diff}"

    def paired(a, b):
        return float(np.median([x - y for x, y in
                                zip(samples[a], samples[b])]))

    # dispatch_overlap: what overlap=True buys the monolithic decomposition
    # (allreduce dispatch pipelining only — the PR-5 measurement).
    # compute_overlap: what overlap=True buys the segmented decomposition
    # over its own barrier twin. On host-CPU devices compute and
    # communication share the same cores, so this is ~0 there; on real
    # accelerators it is the backward-compute window the per-bucket
    # allreduces hide under. delta: the end-to-end headline — the
    # segmented-overlapped step vs the monolithic barrier baseline.
    dispatch_overlap = paired("mono_barrier", "mono_overlap")
    compute_overlap = paired("seg_barrier", "segmented")
    delta = paired("mono_barrier", "segmented")
    print(f"overlap/train_step/dispatch_overlap,0.0,"
          f"{dispatch_overlap:+.2f}ms ({step_times['mono_barrier']:.1f}ms "
          f"-> {step_times['mono_overlap']:.1f}ms)")
    print(f"overlap/train_step/compute_overlap,0.0,"
          f"{compute_overlap:+.2f}ms ({step_times['seg_barrier']:.1f}ms "
          f"-> {step_times['segmented']:.1f}ms)")
    print(f"overlap/train_step/delta,0.0,{delta:+.2f}ms "
          f"segments={n_segments} "
          f"({step_times['mono_barrier']:.1f}ms -> "
          f"{step_times['segmented']:.1f}ms)")
    if M >= 8:
        # CI non-regression gate (8-device leg): the segmented-overlapped
        # step must not lose to the monolithic barrier baseline, and must
        # buy strictly more than dispatch-only pipelining did
        assert delta >= 0.0, \
            f"segmented step regressed vs monolithic barrier: {delta:+.2f}ms"
        assert delta > dispatch_overlap, \
            (f"segmented win ({delta:+.2f}ms) did not beat dispatch-only "
             f"overlap ({dispatch_overlap:+.2f}ms)")

    section = {
        "devices": M, "topology": autotune.topo_key(topo),
        "microbench": {
            "buckets": K, "bucket_bytes": n * 4, "plan": op_b.plan,
            "barrier_us": barrier_us, "overlapped_us": overlapped_us,
            "speedup": speedup,
        },
        "amortization": {"init_us": init_us, "start_us": start_us,
                         "curve": amortization},
        "train_step": {
            "buckets": n_buckets["segmented"],
            "mono_buckets": n_buckets["mono_barrier"],
            "segments": n_segments,
            "mono_barrier_ms": step_times["mono_barrier"],
            "mono_overlap_ms": step_times["mono_overlap"],
            "seg_barrier_ms": step_times["seg_barrier"],
            "segmented_ms": step_times["segmented"],
            "dispatch_overlap_ms": dispatch_overlap,
            "compute_overlap_ms": compute_overlap,
            "delta_ms": delta,
        },
    }
    if out_path:
        path = pathlib.Path(out_path)
        data = json.loads(path.read_text()) if path.exists() else {}
        data["overlap"] = section
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(data, indent=1, sort_keys=True))
        print(f"overlap/artifact,0.0,{path}")


def codec_kernel_mode(out_path=None):
    """Codec-kernel microbench: fused Pallas lowerings vs jnp reference.

    For every fused codec (compress.fused_codecs()), wall-clock the two
    fused entry points against the jnp reference path (both jitted, timed
    iterations are executable-cache hits; the jnp variant is traced under
    compress.jnp_reference_paths() so its compiled program never routes a
    kernel), then report the ANALYTIC memory traffic per stage
    (kernels.codec.memory_traffic — the HBM passes each path makes) and
    the roofline seconds those bytes cost at HBM_BW. On CPU the fused
    kernels run in interpret mode, so wall-clock favors jnp — the traffic
    model is the TPU-relevant number, and the acceptance bar (fused moves
    <= half the jnp bytes on at least one codec) is asserted here.

    Also re-measures zlib_sim's entropy-backed wire ratio (satellite: the
    ratio is measured, not assumed). With OUT_JSON, merges a
    ``codec_kernels`` section into the artifact and writes the standalone
    results/BENCH_codec_kernels.json next to it.
    """
    from repro.kernels import codec as ckern
    from repro.roofline.terms import HBM_BW

    S, W = 8, 8
    L = 16 * compress.BLOCK          # 4096 elems/slice, 32 KiB wire payload
    n_elems = S * L
    key = jax.random.PRNGKey(7)
    x2d = jax.random.normal(key, (S, L), jnp.float32) * 0.01
    err = jnp.zeros_like(x2d)
    rows = []
    for name in compress.fused_codecs():
        cd = compress.codec(name)
        # fused path: traced with the toggle on (the default)
        f_ef = jax.jit(lambda x, e, _c=cd: _c.encode_with_feedback(x, e))
        us_f_ef, (comp_f, _) = bench(lambda a: f_ef(a, err), x2d, n=3)
        f_dr = jax.jit(lambda c, _c=cd: _c.decode_reduce(c, L))
        us_f_dr, out_f = bench(lambda c: f_dr(c), comp_f, n=3)
        # jnp reference: traced (compiled) with the toggle off, so the
        # cached executable stays the jnp program after the toggle returns
        with compress.jnp_reference_paths():
            j_ef = jax.jit(lambda x, e, _c=cd: _c.encode_with_feedback(x, e))
            us_j_ef, (comp_j, _) = bench(lambda a: j_ef(a, err), x2d, n=3)
            j_dr = jax.jit(lambda c, _c=cd: _c.decode_reduce(c, L))
            us_j_dr, out_j = bench(lambda c: j_dr(c), comp_j, n=3)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_j),
                                   rtol=1e-6, atol=1e-5 * W)
        wb_per_elem = cd.wire_bytes(comp_f) / float(n_elems)
        traffic = ckern.memory_traffic(wb_per_elem, n_elems, W=W)
        row = {"codec": name, "elems": n_elems,
               "wire_bytes_per_elem": wb_per_elem,
               "wall_us": {"encode_feedback": {"fused": us_f_ef,
                                               "jnp": us_j_ef},
                           "decode_reduce": {"fused": us_f_dr,
                                             "jnp": us_j_dr}},
               "traffic": traffic,
               "roofline_s": {
                   stage: {path: traffic[stage][f"{path}_bytes"] / HBM_BW
                           for path in ("jnp", "fused")}
                   for stage in traffic}}
        rows.append(row)
        for stage in ("encode_feedback", "decode_reduce"):
            t = traffic[stage]
            frac = t["fused_bytes"] / t["jnp_bytes"]
            print(f"codec_kernel/{name}/{stage},"
                  f"{row['wall_us'][stage]['fused']:.1f},"
                  f"jnp_us={row['wall_us'][stage]['jnp']:.1f} "
                  f"fused_bytes={t['fused_bytes']:.0f} "
                  f"jnp_bytes={t['jnp_bytes']:.0f} "
                  f"traffic_frac={frac:.3f} "
                  f"roofline_fused_us="
                  f"{row['roofline_s'][stage]['fused'] * 1e6:.2f}")
    # acceptance: fused moves <= half the jnp bytes on >= 1 codec (it holds
    # for all of them on the encode side; assert the weakest form here)
    halved = [r["codec"] for r in rows
              if r["traffic"]["encode_feedback"]["fused_bytes"]
              <= 0.5 * r["traffic"]["encode_feedback"]["jnp_bytes"]]
    assert halved, rows
    print(f"codec_kernel/traffic_halved,0.0,{' '.join(halved)}")
    # zlib_sim: the wire ratio is measured (byte-entropy stage), not assumed
    zl = compress.codec("zlib_sim")
    ids = (np.arange(4096, dtype=np.int64) * 2654435761) % 50257
    sample = jnp.asarray(ids, jnp.float32).reshape(1, -1)
    measured = 4.0 * sample.size / zl.wire_bytes(zl.encode(sample))
    zlib_row = {"codec": "zlib_sim", "meta_ratio": zl.meta.wire_ratio,
                "measured_ratio": float(measured)}
    print(f"codec_kernel/zlib_sim/measured_ratio,0.0,"
          f"meta={zl.meta.wire_ratio:.2f}x measured={measured:.2f}x")
    section = {"devices": int(DC), "block": compress.BLOCK,
               "slices": S, "world": W, "elems_per_slice": L,
               "fused_codecs": list(compress.fused_codecs()),
               "rows": rows, "traffic_halved": halved,
               "zlib_sim": zlib_row,
               "note": "wall_us on CPU runs the kernels in interpret mode; "
                       "traffic/roofline_s are the analytic HBM passes"}
    if out_path:
        path = pathlib.Path(out_path)
        data = json.loads(path.read_text()) if path.exists() else {}
        data["codec_kernels"] = section
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(data, indent=1, sort_keys=True))
        solo = path.parent / "BENCH_codec_kernels.json"
        solo.write_text(json.dumps(section, indent=1, sort_keys=True))
        print(f"codec_kernel/artifact,0.0,{path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--calibrate", metavar="OUT_JSON", default=None,
                    help="run the calibration sweep and write the tuning "
                         "table artifact instead of the measure rows")
    ap.add_argument("--overlap", metavar="OUT_JSON", nargs="?", const="",
                    default=None,
                    help="run the persistent-op overlap leg (barrier vs "
                         "overlapped bucketed sync + amortization curve); "
                         "with OUT_JSON, merge an 'overlap' section into "
                         "the artifact")
    ap.add_argument("--codec-kernels", metavar="OUT_JSON", nargs="?",
                    const="", default=None,
                    help="run the codec-kernel microbench (fused Pallas "
                         "lowerings vs jnp reference: wall-clock, analytic "
                         "memory traffic, roofline seconds); with OUT_JSON, "
                         "merge a 'codec_kernels' section into the artifact")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="enable the telemetry tracer for the whole run and "
                         "export a Chrome/Perfetto trace JSON at the end "
                         "(orthogonal to the mode flags)")
    args = ap.parse_args()
    if BACKEND.multiprocess and not args.calibrate:
        raise SystemExit(
            "multi-process runs support --calibrate only; the measure/"
            "overlap/codec-kernel legs are single-process benchmarks "
            "(run them without the repro.distributed launcher)")
    if args.trace:
        telemetry.enable()
    if args.calibrate:
        calibrate_mode(args.calibrate)
    elif args.overlap is not None:
        overlap_mode(args.overlap or None)
    elif args.codec_kernels is not None:
        codec_kernel_mode(args.codec_kernels or None)
    else:
        measure_mode()
    if args.trace:
        trace = telemetry.export_chrome_trace(args.trace)
        print(f"trace/artifact,0.0,{args.trace} "
              f"events={len(trace['traceEvents'])}")

"""Wall-clock the real shard_map collective implementations on 8 CPU host
devices (launched by benchmarks/run.py with XLA_FLAGS set). CPU collective
timing does not model ICI, but the ROUND-COUNT ordering (pip_mcoll fewer
rounds than flat algorithms) shows up in dispatch overhead, and correctness
of every algorithm is asserted on the way."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcoll
from repro.core.topology import Topology

N, P = 4, 2
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)


def bench(fn, x, n=20):
    out = jax.block_until_ready(fn(x))
    t0 = time.time()
    for _ in range(n):
        out = jax.block_until_ready(fn(x))
    return (time.time() - t0) / n * 1e6, out


for nbytes in (256, 65536):
    m = nbytes // 4 // (N * P)
    x = jnp.arange(N * P * max(m, 1), dtype=jnp.float32)
    for algo in mcoll.algorithms("allgather"):
        fn = mcoll.collective_fn(mesh, topo, "allgather", algo, stacked=True)
        us, out = bench(fn, x)
        ok = bool((np.asarray(out)[0] == np.asarray(x)).all())
        assert ok, algo
        print(f"measured/allgather/{algo}/{nbytes}B,{us:.1f},8cpu-dev ok")
    for algo in mcoll.algorithms("allreduce"):
        z = jnp.ones((N * P, max(m, 1)), jnp.float32)
        fn = mcoll.collective_fn(mesh, topo, "allreduce", algo)
        us, out = bench(fn, z)
        print(f"measured/allreduce/{algo}/{nbytes}B,{us:.1f},8cpu-dev ok")

"""Benchmark harness — one function per paper table/figure plus framework
benches. Prints ``name,us_per_call,derived`` CSV rows.

  fig1_scatter    paper Figure 1: MPI_Scatter small messages, 128x18
  fig2_allgather  paper Figure 2: MPI_Allgather 16..512B, 128x18
  tpu_hierarchy   the TPU-native adaptation: pod-level hierarchical gains
  measured_rounds wall-clock of the real shard_map collectives on 8 CPU
                  devices (subprocess; relative ordering, not TPU time);
                  runs through repro.core.runtime's compiled-callable
                  cache and reports its hit/miss totals
  autotune_table  algorithm crossover tables for all six collectives
                  (model priors + measured comparison when calibrated)
  kernel_bench    Pallas kernel interpret-mode vs jnp-ref wall time
  roofline_summary aggregates results/dryrun.jsonl (if present)

``python benchmarks/run.py calibrate`` runs the measured calibration sweep
plus the persistent-op overlap leg and the codec-kernel microbench on the
8-CPU-device mesh, persisting the selection subsystem's tuning table, an
``overlap`` section (barrier vs overlapped bucketed sync, init/start
amortization curve, train-step delta), and a ``codec_kernels`` section
(fused Pallas codec lowerings vs jnp reference: wall-clock, analytic HBM
traffic, roofline seconds) to ``results/BENCH_collectives.json`` (the CI
perf artifact; the codec section is also written standalone as
``results/BENCH_codec_kernels.json``).

The paper's absolute numbers come from an OPA cluster; figures here are the
alpha-beta model (core/costmodel.py) instantiated with the paper's cluster
constants — EXPERIMENTS.md compares the modeled speedups against the
paper's measured claims.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

from repro.core import autotune, costmodel
from repro.core.topology import Topology

REPO = pathlib.Path(__file__).resolve().parent.parent
ROWS = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}")


def fig1_scatter():
    """Paper Fig.1: scatter small messages on 128 nodes x 18 ppn."""
    topo = Topology(128, 18)
    lib_nets = {"openmpi": costmodel.paper_cluster_openmpi(),
                "mvapich2": costmodel.paper_cluster_cma(),
                "intelmpi": costmodel.paper_cluster_posix_shmem()}
    for m in (16, 32, 64, 128, 256, 512):
        pip = costmodel.scatter_cost("pip_mcoll", topo, m,
                                     costmodel.paper_cluster_pip())
        emit(f"fig1/pip_mcoll/{m}B", pip.us(),
             f"rounds={pip.inter_rounds}")
        best = None
        for lib, net in lib_nets.items():
            c = costmodel.scatter_cost("binomial", topo, m, net)
            emit(f"fig1/{lib}/{m}B", c.us(), f"rounds={c.inter_rounds}")
            best = min(best or c.time, c.time)
        emit(f"fig1/speedup_vs_best/{m}B", 0.0,
             f"{best / pip.time:.2f}x")


def fig2_allgather():
    """Paper Fig.2: allgather 16..512B on 128x18 (paper: up to 4.6x)."""
    topo = Topology(128, 18)
    lib_nets = {"openmpi": costmodel.paper_cluster_openmpi(),
                "mvapich2": costmodel.paper_cluster_cma(),
                "intelmpi": costmodel.paper_cluster_posix_shmem(),
                "pip_mpich": costmodel.paper_cluster_pip_mpich()}
    for m in (16, 32, 64, 128, 256, 512):
        pip = costmodel.allgather_cost("pip_mcoll", topo, m,
                                       costmodel.paper_cluster_pip())
        emit(f"fig2/pip_mcoll/{m}B", pip.us(), f"rounds={pip.inter_rounds}")
        best_flat = None
        best_hier = None
        for lib, net in lib_nets.items():
            algo = "bruck" if lib == "pip_mpich" else "recursive_doubling"
            c = costmodel.allgather_cost(algo, topo, m, net)
            emit(f"fig2/{lib}/{m}B", c.us(), f"rounds={c.inter_rounds}")
            best_flat = min(best_flat or c.time, c.time)
            h = costmodel.allgather_cost("single_leader", topo, m, net)
            best_hier = min(best_hier or h.time, h.time)
        emit(f"fig2/speedup_bracket/{m}B", 0.0,
             f"[{best_hier / pip.time:.2f}x..{best_flat / pip.time:.2f}x]"
             f" paper_claim=4.6x@64B")


def tpu_hierarchy():
    """Beyond-paper: the adaptation on TPU v5e meshes."""
    for name, topo, net in (
            ("pod16x16_ici", Topology(16, 16), costmodel.tpu_v5e_pod()),
            ("dcn2x256", Topology(2, 256), costmodel.tpu_v5e_multipod()),
            ("dcn32x256", Topology(32, 256), costmodel.tpu_v5e_multipod())):
        for m in (256, 4096, 1 << 16):
            pip = costmodel.allgather_cost("pip_mcoll", topo, m, net)
            sl = costmodel.allgather_cost("single_leader", topo, m, net)
            emit(f"tpu/{name}/allgather/{m}B/pip_mcoll", pip.us(),
                 f"rounds={pip.inter_rounds}")
            emit(f"tpu/{name}/allgather/{m}B/single_leader", sl.us(),
                 f"speedup={sl.time / pip.time:.2f}x")


def _bench_subprocess(extra_args, prefix: str, timeout: int,
                      fatal: bool) -> None:
    """Run measure_collectives.py on 8 forced CPU host devices (subprocess
    so this process keeps 1 device) and re-emit its ``prefix``-tagged CSV
    rows. ``fatal`` makes a subprocess failure fail this run (CI points at
    the right step) instead of degrading to an ERROR row."""
    script = REPO / "benchmarks" / "measure_collectives.py"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run([sys.executable, str(script), *extra_args],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    if out.returncode != 0:
        emit(f"{prefix}ERROR", 0.0, out.stderr[-200:].replace(",", ";"))
        if fatal:
            raise SystemExit(1)
        return
    for line in out.stdout.splitlines():
        if line.startswith(prefix):
            parts = line.split(",")
            emit(parts[0], float(parts[1]), ",".join(parts[2:]))


def _bench_multiprocess(extra_args, prefix: str, timeout: int,
                        processes: int, devices: int) -> None:
    """Run measure_collectives.py under the repro.distributed launcher:
    ``processes`` coordinated jax.distributed workers with ``devices``
    forced CPU host devices each. The launcher re-prints rank 0's stdout,
    so row re-emission works exactly like :func:`_bench_subprocess`;
    failures are fatal (the calibrate leg is a CI gate)."""
    script = REPO / "benchmarks" / "measure_collectives.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run(
        [sys.executable, "-m", "repro.distributed.launch",
         "--processes", str(processes), "--devices", str(devices),
         "--timeout", str(timeout), "--", str(script), *extra_args],
        env=env, capture_output=True, text=True, timeout=timeout + 120)
    if out.returncode != 0:
        emit(f"{prefix}ERROR", 0.0,
             (out.stderr or out.stdout)[-400:].replace(",", ";"))
        raise SystemExit(1)
    for line in out.stdout.splitlines():
        if line.startswith(prefix):
            parts = line.split(",")
            emit(parts[0], float(parts[1]), ",".join(parts[2:]))


def measured_rounds():
    """Wall-clock the real shard_map algorithms (8 CPU host devices,
    subprocess so this process keeps 1 device). CPU timings demonstrate
    round-count ordering only — derived column has modeled TPU time.
    The subprocess drives every call through repro.core.runtime, so timed
    iterations are compiled-callable cache hits (no re-trace in the
    numbers); the measured/runtime_cache row carries the hit/miss totals."""
    _bench_subprocess([], "measured/", timeout=900, fatal=False)


def autotune_table():
    """Model-prior crossover tables for all six collectives (algorithm AND
    chunk-count plans), plus (when a calibration artifact exists) the
    measured-vs-model comparison and the measured pipeline crossovers."""
    from repro.core import mcoll
    topo = Topology(16, 16, node_link="tpu_v5e_ici", local_link="tpu_v5e_ici")
    net = costmodel.net_for(topo)
    selector = autotune.Selector()
    for coll in sorted(costmodel.COST_FNS):
        table = selector.crossover_table(coll, topo)
        crossovers = []
        prev = None
        for size in sorted(table):
            plan = autotune.encode_plan(table[size].algo,
                                        table[size].chunks,
                                        table[size].codec)
            if plan != prev:
                crossovers.append(f"{size}B->{plan}")
                prev = plan
        emit(f"autotune/{coll}/16x16", 0.0, " ".join(crossovers))
    # modeled pipelining crossover per chunk-capable pair: the size where
    # the optimally-chunked variant starts beating chunks=1
    for coll in sorted(costmodel.COST_FNS):
        for algo in sorted(mcoll.CHUNKED[coll]):
            xo = costmodel.pipeline_crossover_bytes(coll, algo, topo, net)
            emit(f"autotune/pipeline_crossover/{coll}/{algo}/16x16", 0.0,
                 f"model_crossover={xo}B" if xo else "no-crossover")
    # modeled codec crossovers (compression axis): per codec-capable pair
    # and codec, the size where the compressed plan beats lossless
    from repro.core import compress
    for coll in sorted(costmodel.COST_FNS):
        for algo in sorted(mcoll.COMPRESSED[coll]):
            for cd in compress.lossy():
                xo = costmodel.compressed_crossover_bytes(coll, algo, topo,
                                                          net, cd)
                emit(f"autotune/codec_crossover/{coll}/{algo}@{cd}/16x16",
                     0.0, f"model_crossover={xo}B" if xo else "no-crossover")
    art = REPO / "results" / "BENCH_collectives.json"
    if art.exists():
        data = json.loads(art.read_text())
        agree = sum(1 for c in data.get("model_vs_measured", ())
                    if c["agree"])
        total = len(data.get("model_vs_measured", ()))
        emit("autotune/model_vs_measured", 0.0,
             f"agree={agree}/{total} topo={data.get('topology')}")
        for c in data.get("model_vs_measured", ()):
            if not c["agree"]:
                emit(f"autotune/disagree/{c['collective']}/{c['nbytes']}B",
                     c["measured_us"],
                     f"measured={c['measured_algo']} "
                     f"prior={c['prior_algo']}")
        for row in data.get("pipeline_crossover", ()):
            emit(f"autotune/measured_pipeline/{row['collective']}/"
                 f"{row['algo']}", 0.0,
                 f"model_crossover={row['model_crossover_bytes']}B "
                 f"measured_sizes={sorted(row['measured_us_by_plan'])}")
        for row in data.get("compression", ()):
            emit(f"autotune/compression/{row['codec']}", 0.0,
                 f"ratio={row['achieved_ratio']:.2f}x "
                 f"err={row['achieved_abs_error']:.2e} "
                 f"bound={row['bound_abs_tolerance']:.2e} "
                 f"crossover={row['model_crossover_vs_lossless_bytes']}B "
                 f"budget_crossover="
                 f"{row['budget_selection_crossover_bytes']}B")


def calibrate_collectives(processes: int = 1, devices: int = 4):
    """Run the measured calibration sweep and persist the tuning-table
    artifact to results/BENCH_collectives.json for CI upload +
    autotune_table. Default: the 8-CPU-device single-process mesh
    (subprocess, like measured_rounds); ``processes > 1`` runs it under
    the repro.distributed launcher instead — a real multi-controller
    ``(processes, devices)`` mesh with the node axis on the process
    boundary, rank 0 writing the merged artifact."""
    out_json = REPO / "results" / "BENCH_collectives.json"
    if processes > 1:
        _bench_multiprocess(["--calibrate", str(out_json)], "calibrate/",
                            timeout=3000, processes=processes,
                            devices=devices)
    else:
        _bench_subprocess(["--calibrate", str(out_json)], "calibrate/",
                          timeout=1800, fatal=True)


def overlap_collectives():
    """Run the persistent-op overlap leg (barrier vs overlapped bucketed
    sync, init/start amortization, train-step delta) on the 8-CPU-device
    mesh and merge its ``overlap`` section into the calibration artifact
    (run AFTER calibrate_collectives — the calibrate mode rewrites the
    file)."""
    out_json = REPO / "results" / "BENCH_collectives.json"
    _bench_subprocess(["--overlap", str(out_json)], "overlap/",
                      timeout=1800, fatal=True)


def codec_kernel_collectives():
    """Run the codec-kernel microbench (fused Pallas codec lowerings vs jnp
    reference: wall-clock, analytic HBM traffic, roofline seconds) on the
    8-CPU-device mesh and merge its ``codec_kernels`` section into the
    calibration artifact (run AFTER calibrate_collectives — the calibrate
    mode rewrites the file). Also writes results/BENCH_codec_kernels.json
    as a standalone artifact."""
    out_json = REPO / "results" / "BENCH_collectives.json"
    _bench_subprocess(["--codec-kernels", str(out_json)], "codec_kernel/",
                      timeout=1800, fatal=True)


def kernel_bench():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 2048, 8, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)

    def bench(fn, n=5):
        jax.block_until_ready(fn())  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (time.time() - t0) / n * 1e6

    t_ref = bench(lambda: ref.flash_decode(q, k, v, jnp.int32(S)))
    t_ker = bench(lambda: ops.flash_decode(q, k, v, jnp.int32(S)))
    emit("kernel/flash_decode/ref_jnp", t_ref, "CPU")
    emit("kernel/flash_decode/pallas_interpret", t_ker,
         "interpret-mode; TPU perf modeled in roofline")


def roofline_summary():
    path = REPO / "results" / "dryrun_opt.jsonl"
    if not path.exists():
        path = REPO / "results" / "dryrun.jsonl"
    if not path.exists():
        emit("roofline/NOT_RUN", 0.0, "run repro.launch.dryrun --all first")
        return
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    ok = [r for r in recs if r.get("status") == "ok"
          and not r.get("multi_pod")]
    for r in ok:
        ro = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             ro["step_lower_bound_s"] * 1e6,
             f"bottleneck={ro['bottleneck']};frac="
             f"{ro['roofline_fraction']:.3f};useful="
             f"{ro['useful_ratio']:.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    argv = sys.argv[1:]

    def _flag(name: str, default: int) -> int:
        return int(argv[argv.index(name) + 1]) if name in argv else default

    if "calibrate" in argv:
        # CI smoke: measured calibration sweep + persistent-op overlap leg
        # + codec-kernel microbench -> BENCH_collectives.json (table,
        # crossovers, overlap + codec_kernels sections).
        # ``calibrate --processes K [--devices M]`` runs the sweep on a
        # K-process multi-controller mesh (M CPU devices per process);
        # the overlap/codec legs stay single-process and merge into the
        # same artifact, preserving its backend/process_count stamp.
        calibrate_collectives(processes=_flag("--processes", 1),
                              devices=_flag("--devices", 4))
        overlap_collectives()
        codec_kernel_collectives()
        # the three modes above each rewrite/merge the artifact; validate
        # the final shape so a mode silently dropping a section fails HERE
        from repro.core import artifact as artifact_schema
        artifact_schema.validate_file(
            REPO / "results" / "BENCH_collectives.json")
        emit("calibrate/artifact_schema", 0.0, "all sections validated")
        autotune_table()
        return
    fig1_scatter()
    fig2_allgather()
    tpu_hierarchy()
    autotune_table()
    kernel_bench()
    measured_rounds()
    roofline_summary()


if __name__ == "__main__":
    main()

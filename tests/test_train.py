"""Training semantics: convergence, microbatch-accumulation equivalence,
loss masking, z-loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import decoder
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.train.step import TrainConfig, cross_entropy, train_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_loss_decreases_smollm():
    from repro.launch.train import main
    losses = main(["--arch", "smollm-360m", "--reduced", "--steps", "40",
                   "--batch", "4", "--seq", "64", "--lr", "3e-3",
                   "--log-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[-5:]


def test_microbatch_equivalence():
    """2 microbatches must give (near-)identical updates to 1 full batch."""
    cfg = reduced_config("smollm-360m")
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5,
                             schedule="constant", grad_clip=1e9)
    params = decoder.init(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (4, 32), 0, cfg.vocab)}
    outs = {}
    for nmb in (1, 2):
        tcfg = TrainConfig(optimizer=ocfg, microbatches=nmb,
                           flags=RunFlags(remat="none"))
        opt = adamw.init(params, ocfg)
        new_p, _, m = train_step(params, opt, batch, cfg, tcfg)
        outs[nmb] = (new_p, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=2e-3)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        outs[1][0], outs[2][0])
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    labels = jnp.array([[1, 2, -1, -1]], jnp.int32)
    loss, n = cross_entropy(logits, labels)
    assert int(n) == 2
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


def test_z_loss_penalizes_large_logits():
    labels = jnp.array([[0]], jnp.int32)
    small = jnp.array([[[1.0, 0.0]]])
    big = small * 20
    l_small, _ = cross_entropy(small, labels, z_loss=1e-2)
    l_big, _ = cross_entropy(big, labels, z_loss=1e-2)
    l_big_nz, _ = cross_entropy(big, labels, z_loss=0.0)
    assert float(l_big) - float(l_big_nz) > 0.5  # z-term bites


def test_remat_policies_same_loss():
    cfg = reduced_config("phi3-medium-14b")
    params = decoder.init(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (2, 32), 0, cfg.vocab)}
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5)
    ref = None
    for remat in ("none", "dots", "full"):
        tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat=remat))
        opt = adamw.init(params, ocfg)
        _, _, m = train_step(params, opt, batch, cfg, tcfg)
        if ref is None:
            ref = float(m["loss"])
        else:
            np.testing.assert_allclose(float(m["loss"]), ref, rtol=1e-4)


def test_resolve_plan_boundary_budgets_never_crash():
    """Regression for the boundary-budget crash in the pinned-algo codec
    branch of manual_step._resolve_plan: a positive budget admitting NO
    codec made ``min()`` raise on an empty sequence mid-build. The plan
    must fall back to lossless instead — and real boundary budgets (0.0,
    just-below/at the smallest codec bound, huge) must all resolve."""
    from repro.core import compress as codecs
    from repro.core.topology import Topology
    from repro.train import manual_step

    topo = Topology(1, 1)
    bounds = sorted(e for e in (codecs.meta(n).error_bound
                                for n in codecs.codecs()) if e > 0.0)
    assert bounds, "expected at least one lossy codec in the registry"
    lo = bounds[0]
    for budget in (0.0, lo / 2, lo, lo * 1.01, 1e9):
        name, kw = manual_step._resolve_plan(
            topo, 1 << 16, jnp.float32, "pip_mcoll", None, None, budget)
        assert name == "pip_mcoll"
        if budget < lo:
            assert "codec" not in kw, (budget, kw)

    # the empty-candidate corner itself, pinned down by monkeypatching the
    # admissibility gate (no registry configuration reaches it today, but
    # the crash was one registry edit away)
    orig = codecs.for_budget
    codecs.for_budget = lambda *a, **k: ()
    try:
        name, kw = manual_step._resolve_plan(
            topo, 1 << 16, jnp.float32, "pip_mcoll", None, None, 0.05)
    finally:
        codecs.for_budget = orig
    assert name == "pip_mcoll" and "codec" not in kw, (name, kw)

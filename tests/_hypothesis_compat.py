"""Import-compatible subset of ``hypothesis`` for environments without it.

When the real ``hypothesis`` is installed, its ``given`` / ``settings`` /
``strategies`` are re-exported unchanged and tests get full property-based
search. When it is absent, the shim replays a fixed deterministic sample of
each strategy by expanding the test into ``pytest.mark.parametrize`` cases
(boundary values first, then seeded-random draws), so property tests still
run with reduced rigor instead of erroring at collection.

Only the strategy constructors this suite uses are implemented:
``integers``, ``floats``, ``sampled_from``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    import pytest

    _DEFAULT_EXAMPLES = 10
    _MAX_EXAMPLES_CAP = 25

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def examples(self, rng, k):
            vals = []
            for v in (self.lo, self.hi, (self.lo + self.hi) // 2):
                if v not in vals:
                    vals.append(v)
            while len(vals) < k:
                vals.append(rng.randint(self.lo, self.hi))
            return vals[:k]

    class _Floats:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = float(min_value), float(max_value)

        def examples(self, rng, k):
            vals = [self.lo, self.hi, (self.lo + self.hi) / 2.0]
            while len(vals) < k:
                vals.append(rng.uniform(self.lo, self.hi))
            return vals[:k]

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        def examples(self, rng, k):
            return [self.elements[i % len(self.elements)] for i in range(k)]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    strategies = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        """Record max_examples on the test fn for @given to pick up."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        """Expand a deterministic sample of each strategy into parametrize
        cases. Seeds derive from the test/arg names only, so the replayed
        sample is stable across runs and machines."""
        def deco(fn):
            names = sorted(strats)
            k = getattr(fn, "_shim_max_examples", None) or _DEFAULT_EXAMPLES
            k = max(1, min(int(k), _MAX_EXAMPLES_CAP))
            cols = {
                n: strats[n].examples(
                    random.Random(f"{fn.__name__}::{n}"), k)
                for n in names
            }
            if len(names) == 1:
                cases = cols[names[0]]
            else:
                cases = [tuple(cols[n][i] for n in names) for i in range(k)]
            return pytest.mark.parametrize(",".join(names), cases)(fn)
        return deco

"""Compressed collective subsystem on a (N x P) mesh: every lossy codec's
allreduce vs the exact psum, within the codec's stated bound; the
error_budget=auto path; and error-feedback convergence over steps."""
import sys
N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as Pt

from repro.core import compress, mcoll, runtime
from repro.core.comm import Communicator
from repro.core.topology import Topology

mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology.from_mesh(mesh)
comm = Communicator(mesh, topo)
M = N * P
n = 1000  # non-multiple of world*block on purpose
x = (jax.random.normal(jax.random.PRNGKey(0), (M, n)) * 0.01)
want = np.asarray(x).sum(0)
A = float(np.abs(np.asarray(x)).max())

# 1. every lossy codec, through the Communicator's compiled-callable cache,
# on both the plain and the pipelined compressed allreduce — blocking and
# persistent-nonblocking execution of one plan must agree bitwise
for codec in compress.lossy():
    tol = compress.collective_tolerance(codec, "allreduce", M, A) + 1e-7
    for algo, kw in (("pip_mcoll", {}), ("pip_pipeline", {"chunks": 3})):
        got = np.asarray(comm.allreduce(x, algo=algo, codec=codec, **kw))
        err = max(np.abs(got[d] - want).max() for d in range(M))
        assert err <= tol, (codec, algo, err, tol)
        op = comm.allreduce_init(x, algo=algo, codec=codec, **kw)
        np.testing.assert_array_equal(np.asarray(op.start(x).wait()), got)

# 2. error_budget resolution: auto under a budget conforms to the loosest
# admissible codec's bound; zero budget must reproduce the exact sum
got = np.asarray(comm.allreduce(x, error_budget=0.05))
tol = compress.collective_tolerance("int8_block", "allreduce", M, A) + 1e-7
assert np.abs(got[0] - want).max() <= tol
exact = np.asarray(comm.allreduce(x, error_budget=0.0))
np.testing.assert_allclose(exact[0], want, atol=1e-5 * max(A, 1.0))

# 3. error feedback: accumulated compressed sums track the true accumulated
# sum to within ~one step's residual (no drift), unlike feedback-free
def body(xs, es):
    out, e2 = mcoll.pip_mcoll_allreduce(xs[0], topo, codec="int8_block",
                                        err=es[0])
    return out[None], e2[None]

fn = jax.jit(runtime.sharded(
    body, mesh,
    in_specs=(Pt(("node", "local"), None), Pt(("node", "local"), None)),
    out_specs=(Pt(("node", "local"), None), Pt(("node", "local"), None)),
    check=False))
err_state = jnp.zeros((M, n), jnp.float32)
zeros = jnp.zeros((M, n), jnp.float32)
acc_fb = np.zeros(n)
acc_nofb = np.zeros(n)
T = 20
for _ in range(T):
    out, err_state = fn(x, err_state)
    acc_fb += np.asarray(out)[0]
    out2, _ = fn(x, zeros)
    acc_nofb += np.asarray(out2)[0]
lag_fb = np.abs(acc_fb - want * T).max()
lag_nofb = np.abs(acc_nofb - want * T).max()
assert lag_fb <= lag_nofb + 1e-9, (lag_fb, lag_nofb)
assert lag_fb <= compress.collective_tolerance("int8_block", "allreduce",
                                               M, A) * 4, lag_fb

rel = np.abs(acc_fb / T - want).max() / (np.abs(want).max() + 1e-9)
print(f"compressed_allreduce N={N} P={P}: OK rel_err={rel:.4f} "
      f"fb_lag={lag_fb:.2e} nofb_lag={lag_nofb:.2e}")

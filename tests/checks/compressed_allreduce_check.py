"""int8-wire allreduce vs exact psum on a (N x P) mesh."""
import sys
N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as Pt

from repro.core import runtime
from repro.core.topology import Topology
from repro.optim.compress import compressed_allreduce

mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)
M = N * P
n = 1000  # non-multiple of world*block on purpose
x = (jax.random.normal(jax.random.PRNGKey(0), (M, n)) * 0.01)

def body(xs):
    return compressed_allreduce(xs[0], topo)[None]

fn = jax.jit(runtime.sharded(body, mesh,
                             in_specs=(Pt(("node", "local"), None),),
                             out_specs=Pt(("node", "local"), None),
                             check=False))
got = np.asarray(fn(x))
want = np.asarray(x).sum(0)
# every device's copy approximates the exact sum within quantization error
scale_bound = np.abs(np.asarray(x)).max() / 127.0 * (M + 1)
for d in range(M):
    err = np.abs(got[d] - want).max()
    assert err <= scale_bound, (d, err, scale_bound)
rel = np.abs(got[0] - want).max() / (np.abs(want).max() + 1e-9)
print(f"compressed_allreduce N={N} P={P}: OK rel_err={rel:.4f}")

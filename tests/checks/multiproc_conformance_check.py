"""Multi-process conformance: every collective x algorithm, bitwise vs the
single-process XLA reference — plus the calibrate-merge and data-pipeline
legs, amortizing one multi-controller spawn.

Usage (via tests/subproc.py): ``run_check(script, procs * dev, procs,
dev)``. This parent process sees ``procs * dev`` forced host devices and
computes the single-process reference outputs on a ``(procs, dev)`` mesh;
it then spawns ``procs`` coordinated ``jax.distributed`` workers with
``dev`` devices each (``repro.distributed.launch`` overrides the forced
device count per child) running :func:`worker` over the identical
operands. Operands are ``runtime.example_input``'s exact small integers,
so float reductions are order-independent-exact and parity is bitwise.

The worker leg also asserts the process-aware topology (node axis =
process boundary -> ``host_ipc`` inter / ``host_cpu`` intra links), runs a
mini ``comm.calibrate`` whose per-rank tables rank 0 merges and saves, and
returns this process's data-pipeline slice so the parent can check the
K-process global batch is bitwise the 1-process batch.
"""
import pathlib
import sys
import tempfile

import numpy as np

PAYLOAD_NBYTES = 4096


def _plans(runtime, mcoll, autotune, topo):
    for name in runtime.collectives():
        for algo in mcoll.algorithms(name):
            if algo in autotune.candidates(name, topo):
                yield name, algo


def worker(ref_path: str, procs: int, dev: int):
    from repro.distributed import backend as dist
    be = dist.auto_initialize()  # before any device access
    import jax
    from repro.core import autotune, mcoll, runtime
    from repro.core.comm import Communicator
    from repro.core.topology import Topology
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_process_mesh

    assert be.multiprocess and jax.process_count() == procs
    mesh = make_process_mesh()
    assert mesh.devices.shape == (procs, dev), mesh.devices.shape
    topo = Topology.from_mesh(mesh)
    # the tentpole's topology claim: the process boundary splits the link
    # classes, so intra and inter rows never alias in the tuning table
    assert topo.link_names == ("host_ipc", "host_cpu"), topo.link_names
    key = autotune.topo_key(topo)
    assert key == f"{procs}x{dev}/host_ipc/host_cpu", key

    comm = Communicator(mesh, topo)
    refs = np.load(ref_path)
    failures, checked = [], 0
    for name, algo in _plans(runtime, mcoll, autotune, topo):
        x = runtime.example_input(name, topo, PAYLOAD_NBYTES)
        out = getattr(comm, name)(x, algo=algo)
        got = dist.to_host(out)
        want = refs[f"{name}/{algo}"]
        if got.shape != want.shape or got.dtype != want.dtype \
                or not (got == want).all():
            failures.append(f"{name}/{algo}")
        checked += 1

    # calibrate-merge leg: every rank sweeps, rank 0 folds + saves once
    table_path = dist.scratch_dir() / "merged_table.json"
    rows = comm.calibrate(names=("allreduce",), sizes=(PAYLOAD_NBYTES,),
                          iters=2, codecs=(), path=str(table_path))
    assert rows, "calibrate produced no rows"

    # data-pipeline host sharding: this process generates only its slice
    ds = SyntheticLM(vocab=64, seq_len=32, global_batch=2 * procs, seed=3)
    assert ds.host_batch == 2 and ds.host_offset == 2 * be.process_index
    return {"rank": be.process_index, "topo_key": key, "checked": checked,
            "failures": failures, "table_path": str(table_path),
            "tokens": ds.batch(step=5)["tokens"]}


def main() -> None:
    procs, dev = int(sys.argv[1]), int(sys.argv[2])
    import jax
    from repro.core import autotune, mcoll, runtime
    from repro.core.autotune import TuningTable
    from repro.core.comm import Communicator
    from repro.core.topology import Topology

    assert jax.device_count() == procs * dev, jax.device_count()
    mesh = jax.make_mesh((procs, dev), ("node", "local"))
    topo = Topology.from_mesh(mesh)
    assert topo.link_names == ("host_cpu", "host_cpu"), topo.link_names
    comm = Communicator(mesh, topo)
    refs = {}
    for name, algo in _plans(runtime, mcoll, autotune, topo):
        x = runtime.example_input(name, topo, PAYLOAD_NBYTES)
        refs[f"{name}/{algo}"] = np.asarray(getattr(comm, name)(x,
                                                                algo=algo))
    ref_path = pathlib.Path(tempfile.mkdtemp(prefix="mp_conf_")) / "ref.npz"
    np.savez(ref_path, **refs)

    from repro.distributed import launch
    results = launch.run(worker, str(ref_path), procs, dev,
                         processes=procs, devices_per_process=dev,
                         timeout=1500)
    results.sort(key=lambda r: r["rank"])
    assert [r["rank"] for r in results] == list(range(procs))
    for r in results:
        assert not r["failures"], \
            f"rank {r['rank']} bitwise mismatches: {r['failures']}"
        assert r["checked"] == len(refs), (r["checked"], len(refs))

    # merged tuning table: one file, rank 0's fold, keyed on the
    # process-aware topology with distinct intra/inter link classes
    table = TuningTable.load(results[0]["table_path"])
    key = results[0]["topo_key"]
    plans = table.entries[key]["allreduce"]["float32"]
    assert any(algos for algos in plans.values()), table.entries

    # K-process global batch == what a 1-process run generates (this parent
    # IS the 1-process run: jax.process_count() == 1 here)
    from repro.data.pipeline import SyntheticLM
    single = SyntheticLM(vocab=64, seq_len=32, global_batch=2 * procs,
                         seed=3).batch(step=5)["tokens"]
    stacked = np.concatenate([r["tokens"] for r in results])
    np.testing.assert_array_equal(stacked, single)

    print(f"MULTIPROC_CONFORMANCE_OK procs={procs} dev={dev} "
          f"plans={len(refs)} topo={key}")


if __name__ == "__main__":
    main()

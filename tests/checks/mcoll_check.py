"""Exhaustive correctness check of every mcoll algorithm on a (N, P) mesh.

Usage: mcoll_check.py N P   (run under XLA_FLAGS device_count = N*P)
Asserts every collective x algorithm x root/radix variant matches the pure
numpy oracle on every device. Exit 0 = all good.
"""
import sys

N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.core import mcoll, runtime

M = N * P
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)
checks = 0


def ag_oracle(x):
    return np.array(x)


def check_allgather():
    global checks
    m = 3
    x = jnp.arange(M * m, dtype=jnp.float32)
    for algo in mcoll.algorithms("allgather"):
        if algo == "recursive_doubling" and (M & (M - 1)):
            continue
        fn = runtime.build(mesh, topo, "allgather", algo, stacked=True)
        out = np.array(fn(x))
        assert out.shape == (M, M * m)
        for d in range(M):
            np.testing.assert_array_equal(out[d], np.array(x), err_msg=f"{algo} d={d}")
        checks += 1
    for radix in range(2, P + 2):
        fn = runtime.build(mesh, topo, "allgather", "pip_mcoll",
                                 stacked=True, radix=radix)
        out = np.array(fn(x))
        for d in range(M):
            np.testing.assert_array_equal(out[d], np.array(x))
        checks += 1
    # 2-D payloads and other dtypes
    x2 = jnp.arange(M * 2 * 4, dtype=jnp.bfloat16).reshape(M * 2, 4)
    fn = runtime.build(mesh, topo, "allgather", "pip_mcoll", stacked=True)
    out = np.array(fn(x2).astype(jnp.float32))
    for d in range(M):
        np.testing.assert_array_equal(out[d], np.array(x2.astype(jnp.float32)))
    checks += 1


def check_scatter():
    global checks
    m = 2
    x = jnp.arange(M * m, dtype=jnp.float32)
    for algo in mcoll.algorithms("scatter"):
        roots = [0, M // 2, M - 1] if algo != "linear" else [0]
        for root in roots:
            fn = runtime.build(mesh, topo, "scatter", algo, root=root)
            np.testing.assert_array_equal(np.array(fn(x)), np.array(x),
                                          err_msg=f"{algo} root={root}")
            checks += 1
    for radix in range(2, P + 2):
        fn = runtime.build(mesh, topo, "scatter", "pip_mcoll",
                                 radix=radix, root=1)
        np.testing.assert_array_equal(np.array(fn(x)), np.array(x))
        checks += 1


def check_broadcast():
    global checks
    y = jnp.arange(5, dtype=jnp.float32) + 7
    for algo in mcoll.algorithms("broadcast"):
        for root in [0, M - 1]:
            fn = runtime.build(mesh, topo, "broadcast", algo, root=root)
            out = np.array(fn(y))
            for d in range(M):
                np.testing.assert_array_equal(out[d], np.array(y))
            checks += 1


def check_allreduce():
    global checks
    z = (jnp.arange(M * 7, dtype=jnp.float32) % 13).reshape(M, 7)
    expect = np.array(z).sum(0)
    for algo in mcoll.algorithms("allreduce"):
        fn = runtime.build(mesh, topo, "allreduce", algo)
        out = np.array(fn(z))
        for d in range(M):
            np.testing.assert_allclose(out[d], expect, rtol=1e-6)
        checks += 1
    fn = runtime.build(mesh, topo, "allreduce", "pip_mcoll",
                             inter="recursive_doubling")
    out = np.array(fn(z))
    for d in range(M):
        np.testing.assert_allclose(out[d], expect, rtol=1e-6)
    checks += 1


def check_reduce_scatter_alltoall():
    global checks
    s = 2
    w = (jnp.arange(M * M * s, dtype=jnp.float32) % 11).reshape(M, M * s)
    expect = np.array(w).sum(0)
    for algo in mcoll.algorithms("reduce_scatter"):
        fn = runtime.build(mesh, topo, "reduce_scatter", algo)
        np.testing.assert_allclose(np.array(fn(w)).reshape(-1), expect,
                                   rtol=1e-6)
        checks += 1
    a = jnp.arange(M * M * s, dtype=jnp.float32).reshape(M, M, s)
    expect_t = np.array(a).transpose(1, 0, 2)
    for algo in mcoll.algorithms("alltoall"):
        fn = runtime.build(mesh, topo, "alltoall", algo)
        np.testing.assert_array_equal(np.array(fn(a)), expect_t)
        checks += 1


def check_chunked():
    """Pipelined variants with chunk counts that do not divide the payload
    (remainder segments must round-trip exactly on this topology)."""
    global checks
    m = 5
    x = jnp.arange(M * m, dtype=jnp.float32)
    y = jnp.arange(m, dtype=jnp.float32) + 3
    z = (jnp.arange(M * m, dtype=jnp.float32) % 13).reshape(M, m)
    a = jnp.arange(M * M * m, dtype=jnp.float32).reshape(M, M, m)
    for c in (2, 3):
        fn = runtime.build(mesh, topo, "allgather", "ring_pipeline",
                                 stacked=True, chunks=c)
        out = np.array(fn(x))
        for d in range(M):
            np.testing.assert_array_equal(out[d], np.array(x))
        fn = runtime.build(mesh, topo, "scatter", "pip_mcoll",
                                 root=M - 1, chunks=c)
        np.testing.assert_array_equal(np.array(fn(x)), np.array(x))
        fn = runtime.build(mesh, topo, "broadcast", "pip_mcoll",
                                 root=1, chunks=c)
        out = np.array(fn(y))
        for d in range(M):
            np.testing.assert_array_equal(out[d], np.array(y))
        fn = runtime.build(mesh, topo, "allreduce", "pip_pipeline",
                                 chunks=c)
        out = np.array(fn(z))
        for d in range(M):
            np.testing.assert_allclose(out[d], np.array(z).sum(0), rtol=1e-6)
        fn = runtime.build(mesh, topo, "alltoall", "pip_pipeline",
                                 chunks=c)
        np.testing.assert_array_equal(np.array(fn(a)),
                                      np.array(a).transpose(1, 0, 2))
        checks += 5


check_allgather()
check_scatter()
check_broadcast()
check_allreduce()
check_reduce_scatter_alltoall()
check_chunked()
print(f"mcoll_check N={N} P={P}: {checks} checks OK")

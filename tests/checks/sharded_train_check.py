"""Full pjit train step on a small (data x model) mesh with the production
sharding rules: params FSDP+TP sharded, batch data-sharded; loss finite and
matches the single-logical-device value."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.launch import specs
from repro.models import decoder
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.sharding.rules import Rules
from repro.train.step import TrainConfig, train_step
from repro.configs.base import ShapeConfig

cfg = reduced_config("yi-34b")
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = Rules(batch=("data",), fsdp=("data",), tp="model")
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5,
                         schedule="constant")
tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat="none"))
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")

with mesh:
    jitted, (p_sds, o_sds, b_sds) = specs.build_cell(cfg, shape, mesh, rules,
                                                     tcfg=tcfg)
    # materialize real values with the same shardings
    params = decoder.init(jax.random.PRNGKey(0), cfg, mesh=mesh, rules=rules)
    params = jax.tree.map(lambda v, s: jax.device_put(v, s.sharding), params,
                          p_sds)
    opt = adamw.init(params, ocfg)
    opt = jax.tree.map(lambda v, s: jax.device_put(v, s.sharding), opt, o_sds)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    batch = jax.tree.map(lambda v, s: jax.device_put(v, s.sharding), batch,
                         b_sds)
    new_p, new_o, metrics = jitted(params, opt, batch)
    sharded_loss = float(metrics["loss"])

# single-device reference
params1 = decoder.init(jax.random.PRNGKey(0), cfg)
opt1 = adamw.init(params1, ocfg)
batch1 = {k: jnp.asarray(np.asarray(v)) for k, v in batch.items()}
_, _, m1 = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, tcfg))(
    params1, opt1, batch1)
ref_loss = float(m1["loss"])
assert np.isfinite(sharded_loss)
np.testing.assert_allclose(sharded_loss, ref_loss, rtol=2e-2)
print(f"sharded_train_check: OK loss={sharded_loss:.4f} ref={ref_loss:.4f}")

"""algo="auto" end-to-end on a real (N, P) CPU mesh, via the Communicator.

Usage: auto_check.py N P   (run under XLA_FLAGS device_count = N*P)

Asserts, for all six collectives:
  1. Communicator methods with algo="auto" resolve through the selector
     (prior source before calibration) and return bit-identical results to
     every explicit algorithm;
  2. after comm.calibrate, auto resolves from the measured table and
     still returns correct results;
  3. auto and explicit callers share exec-cache entries (auto re-invocation
     is a cache hit, not a fresh compile), and a persistent op initialised
     for the same plan shares the compiled-executable path (repeated start
     never compiles).
"""
import sys

N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import numpy as np

from repro.core import autotune, runtime
from repro.core.comm import Communicator
from repro.core.topology import Topology

mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology.from_mesh(mesh)
assert topo.link_names == ("host_cpu", "host_cpu"), topo.link_names
comm = Communicator(mesh, topo)

checks = 0

# --- 1. auto == every explicit algorithm, prior-sourced -------------------
for name in runtime.collectives():
    for nbytes in (64, 4096):
        x = runtime.example_input(name, topo, nbytes)
        outs = {}
        for algo in autotune.candidates(name, topo):
            outs[algo] = np.asarray(comm.invoke(name, x, algo=algo))
        ref_algo = sorted(outs)[0]
        for algo, out in outs.items():
            if name == "allreduce":  # reduction order: fp tolerance
                np.testing.assert_allclose(out, outs[ref_algo], rtol=1e-6)
            else:
                np.testing.assert_array_equal(out, outs[ref_algo],
                                              err_msg=f"{name}/{algo}")
        comm.selection_stats().reset()
        auto_out = np.asarray(comm.invoke(name, x))
        assert comm.selection_stats().total == 1
        np.testing.assert_allclose(auto_out, outs[ref_algo], rtol=1e-6)
        checks += 1
assert comm.selection_stats().measured == 0, "no calibration yet"

# --- 3. auto shares the exec cache with explicit callers ------------------
runtime.clear_cache()
x = runtime.example_input("allgather", topo, 64)
resolved, _ = runtime.resolve_algo(topo, "allgather", "auto", x)
comm.allgather(x, algo=resolved)   # miss (explicit)
comm.allgather(x)                  # hit (auto)
s = comm.cache_stats()
assert s.exec_misses == 1 and s.exec_hits == 1, s
checks += 1

# --- 3b. persistent op: compile once at init, never at start --------------
op = comm.allgather_init(x, algo=resolved)
comm.cache_stats().reset()
for _ in range(4):
    out_p = np.asarray(op.start(x).wait())
assert comm.cache_stats().exec_misses == 0, "start must never compile"
np.testing.assert_array_equal(out_p, np.asarray(comm.allgather(x)))
op2 = comm.allgather_init(x, algo=resolved)  # same spec: exec-cache hit
assert comm.cache_stats().exec_misses == 0, "re-init must be a hit"
checks += 1

# --- 2. calibration flips resolution to the measured table ----------------
comm.calibrate(sizes=(64, 4096), iters=3)
for name in runtime.collectives():
    x = runtime.example_input(name, topo, 64)
    comm.selection_stats().reset()
    out = np.asarray(comm.invoke(name, x))
    assert comm.selection_stats().measured == 1, name
    assert np.isfinite(out.astype(np.float64)).all()
    checks += 1
sel = autotune.default_selector()
s = sel.choose("allgather", topo, 64)
assert s.source == "measured", s

print(f"auto_check N={N} P={P}: {checks} checks OK")

"""MoE expert-parallel shard_map path vs the single-device local oracle."""
import sys
DP, TP = int(sys.argv[1]), int(sys.argv[2])

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.layers import moe
from repro.sharding.rules import Rules

cfg = reduced_config("qwen3-moe-235b-a22b")
# give the reduced config a TP-divisible expert count & generous capacity so
# the EP path drops nothing (exactness vs oracle requires no drops)
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, n_experts=max(8, TP),
                                 capacity_factor=8.0))
key = jax.random.PRNGKey(0)
p = moe.init(key, cfg)
B, S = DP * 2, 16
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                      jnp.float32).astype(jnp.bfloat16)

y_ref, aux_ref = moe._moe_local(p, x.reshape(-1, cfg.d_model), cfg)
y_ref = y_ref.reshape(B, S, cfg.d_model)

mesh = jax.make_mesh((DP, TP), ("data", "model"))
rules = Rules(batch=("data",), fsdp=(), tp="model")
with mesh:
    y_ep, aux_vec = moe.apply(p, x, cfg, rules=rules, mesh=mesh)

np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                           np.asarray(y_ref, np.float32), rtol=6e-2,
                           atol=6e-2)
# aux loss agrees on average (per-slice estimate vs global)
assert abs(float(aux_vec.mean()) - float(aux_ref)) < 0.5

# compressed combine path: under an error budget the combine all-to-all may
# run through an error-bounded codec; the result must stay within the bf16
# oracle tolerance plus the codec's bound on the combine payload scale
with mesh:
    y_c, _ = moe.apply(p, x, cfg, rules=rules, mesh=mesh, error_budget=0.07)
scale = float(np.abs(np.asarray(y_ref, np.float32)).max())
np.testing.assert_allclose(np.asarray(y_c, np.float32),
                           np.asarray(y_ref, np.float32), rtol=6e-2,
                           atol=6e-2 + 0.07 * scale)
print(f"moe_ep_check DP={DP} TP={TP}: OK (compressed combine "
      f"max_diff={np.abs(np.asarray(y_c, np.float32) - np.asarray(y_ep, np.float32)).max():.3e})")

"""Serving-engine DP token sync through the Communicator's persistent
broadcast op on a real multi-device mesh.

Usage: serve_sync_check.py N P   (run under XLA_FLAGS device_count = N*P)

Asserts the mesh-attached engine produces the same tokens as the sync-free
reference, resolves its per-tick broadcast through the selector
(algo="auto"), and compiles the persistent sync op exactly once — every
later tick is a bare start/wait (no cache lookups, no recompiles).
"""
import sys

N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import runtime
from repro.core.topology import Topology
from repro.models import decoder
from repro.serve.engine import Engine, Request

cfg = reduced_config("smollm-360m")
params = decoder.init(jax.random.PRNGKey(0), cfg)
prompt = np.arange(5, dtype=np.int32) + 2

ref = Engine(params, cfg, max_batch=1, max_len=32)
want = ref.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]

mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology.from_mesh(mesh)
runtime.clear_cache()
runtime.selection_stats().reset()
eng = Engine(params, cfg, max_batch=1, max_len=32, mesh=mesh, topo=topo)
assert eng.sync_algo == "auto"
got = eng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]

assert got.out_tokens == want.out_tokens, (got.out_tokens, want.out_tokens)
assert runtime.selection_stats().total > 0, "sync never hit the selector"
s = runtime.cache_stats()
# persistent sync op: exactly one compile for the whole run, zero repeat
# lookups — every decode tick after the first is a bare start/wait
assert s.exec_misses == 1, s
assert eng._sync_op is not None and eng._sync_op.starts >= 3, \
    (eng._sync_op and eng._sync_op.starts)

# a calibration table loaded mid-serving must re-resolve the sync plan
# (the persistent op is rebound on tuning-table generation bumps) — and
# the engine still produces the reference tokens from the measured plan
op_before = eng._sync_op
# calibrate at the tick payload's exact key: (1,) int32 -> 4-byte bucket
eng.comm.calibrate(names=("broadcast",), sizes=(4,), iters=1,
                   dtype=jnp.int32)
got2 = eng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]
assert got2.out_tokens == want.out_tokens, got2.out_tokens
assert eng._sync_op is not op_before, "sync op never re-resolved"
assert runtime.selection_stats().measured > 0, "measured plan never used"

# group-scoped sync: the tick broadcast runs on the DP ("node") group
# child (comm.split(axes="node")) — TP shards stay independent — and
# still reproduces the reference tokens; calibration for the group plan
# lands on the child's namespaced tuning rows
geng = Engine(params, cfg, max_batch=1, max_len=32, mesh=mesh,
              sync_axes="node")
assert geng.sync_comm is not geng.comm
assert geng.sync_comm.topo.group == "node"
assert geng.sync_comm.topo.world == N
assert geng.sync_comm.selector is geng.comm.selector
got3 = geng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]
assert got3.out_tokens == want.out_tokens, got3.out_tokens
if N > 1:
    assert geng._sync_op is not None and geng._sync_op.starts >= 3
    gop = geng._sync_op
    geng.sync_comm.calibrate(names=("broadcast",), sizes=(4,), iters=1,
                             dtype=jnp.int32)
    got4 = geng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]
    assert got4.out_tokens == want.out_tokens, got4.out_tokens
    assert geng._sync_op is not gop, "group sync op never re-resolved"

# --- Engine.metrics(): tick-latency distribution + occupancy + rebinds ----
m = eng.metrics()
assert m["ticks"] >= 6, m  # two 4-token runs, 3 decode ticks each
assert m["tick_p50_s"] > 0.0 and m["tick_p99_s"] > 0.0, m
assert m["tick_p99_s"] >= m["tick_p50_s"] >= 0.0, m
assert 0.0 < m["slot_occupancy"] <= 1.0, m
assert m["plan_rebinds"] == 1, m  # the mid-serving calibration rebind
assert m["sync_starts"] >= 3, m

# --- rebind storm: a tuning table mutating every run must trip ONE
# rate-limited warning once rebinds pass REBIND_WARN_THRESHOLD ------------
import warnings

from repro.serve.engine import REBIND_WARN_THRESHOLD

with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    for _ in range(REBIND_WARN_THRESHOLD + 2):
        eng.comm.selector.table.generation += 1  # simulate table churn
        eng.run([Request(prompt=prompt.copy(), max_new_tokens=2)])
storm = [w for w in rec if "rebind storm" in str(w.message)]
assert len(storm) == 1, [str(w.message) for w in rec]
assert eng.metrics()["plan_rebinds"] > REBIND_WARN_THRESHOLD

print(f"serve_sync_check N={N} P={P}: OK tokens={got.out_tokens} "
      f"sync_starts={op_before.starts} exec_misses={s.exec_misses} "
      f"recal_plan={eng._sync_op.plan} group={geng.sync_comm.topo.group} "
      f"tick_p50_s={m['tick_p50_s']:.2e} rebinds="
      f"{eng.metrics()['plan_rebinds']}")

"""Serving-engine DP token sync through the selection subsystem on a real
multi-device mesh.

Usage: serve_sync_check.py N P   (run under XLA_FLAGS device_count = N*P)

Asserts the mesh-attached engine produces the same tokens as the sync-free
reference, resolves its per-tick broadcast through the selector
(algo="auto"), and amortizes ticks through the runtime exec cache.
"""
import sys

N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core import runtime
from repro.core.topology import Topology
from repro.models import decoder
from repro.serve.engine import Engine, Request

cfg = reduced_config("smollm-360m")
params = decoder.init(jax.random.PRNGKey(0), cfg)
prompt = np.arange(5, dtype=np.int32) + 2

ref = Engine(params, cfg, max_batch=1, max_len=32)
want = ref.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]

mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology.from_mesh(mesh)
runtime.clear_cache()
before = runtime.selection_stats().total
eng = Engine(params, cfg, max_batch=1, max_len=32, mesh=mesh, topo=topo)
assert eng.sync_algo == "auto"
got = eng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]

assert got.out_tokens == want.out_tokens, (got.out_tokens, want.out_tokens)
assert runtime.selection_stats().total > before, "sync never hit the selector"
s = runtime.cache_stats()
assert s.exec_misses >= 1 and s.exec_hits >= 1, s
print(f"serve_sync_check N={N} P={P}: OK tokens={got.out_tokens} "
      f"exec_hits={s.exec_hits}")

"""Small-mesh dry-run smoke: exercise the full build_cell -> lower ->
compile -> roofline pipeline on an 8-device (4 data x 2 model) mesh for one
arch per family and every shape kind. Validates the deliverable-(e)
machinery end to end without the 512-device cost."""
import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch import specs
from repro.models.decoder import RunFlags
from repro.roofline import hlo as H
from repro.sharding.rules import Rules
from repro.train.step import TrainConfig

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = Rules(batch=("data",), fsdp=("data",), tp="model")
flags = RunFlags()

CELLS = [
    ("smollm-360m", ShapeConfig("t", 256, 8, "train")),
    ("qwen3-moe-235b-a22b", ShapeConfig("p", 512, 8, "prefill")),
    ("rwkv6-1.6b", ShapeConfig("d", 1024, 8, "decode")),
    ("seamless-m4t-large-v2", ShapeConfig("d", 512, 8, "decode")),
]
for arch, shape in CELLS:
    cfg = get_config(arch)
    with mesh:
        jitted, args = specs.build_cell(cfg, shape, mesh, rules,
                                        tcfg=TrainConfig(flags=flags),
                                        flags=flags)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    costs = H.analyze(compiled.as_text(), vmem_tile=(512, 1024,
                                                     cfg.head_dim))
    assert costs.flops > 0, arch
    assert costs.memory_bytes > 0, arch
    peak = (getattr(mem, "argument_size_in_bytes", 0) or 0) + \
        (getattr(mem, "temp_size_in_bytes", 0) or 0)
    assert peak > 0, arch
    print(f"dryrun_smoke {arch} {shape.kind}: flops/dev={costs.flops:.2e} "
          f"coll={costs.collective_bytes:.2e}B OK")
print("dryrun_smoke_check OK")

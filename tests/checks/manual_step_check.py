"""Validate the manual mcoll train step against the pjit reference on a
(node x local) CPU mesh: same loss trajectory, and the compressed variant
stays within quantization tolerance."""
import sys
N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.topology import Topology
from repro.models import decoder
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.train.step import TrainConfig, train_step
from repro.train import manual_step

cfg = reduced_config("smollm-360m")
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                         schedule="constant", grad_clip=1e9)
tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat="none"))
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)

key = jax.random.PRNGKey(0)
params = decoder.init(key, cfg)
opt = adamw.init(params, ocfg)
B, T = N * P * 2, 32
batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                      cfg.vocab)}

# reference: single-device pjit semantics (global batch)
ref_p, ref_o, ref_m = jax.jit(
    lambda p, o, b: train_step(p, o, b, cfg, tcfg))(params, opt, batch)

# manual mcoll step (pip_mcoll allreduce)
step = manual_step.make_manual_train_step(cfg, tcfg, mesh, topo,
                                          algo="pip_mcoll")
err = manual_step.init_error_state(params, False)
man_p, man_o, _, man_m = step(params, opt, err, batch)

np.testing.assert_allclose(float(man_m["loss"]), float(ref_m["loss"]),
                           rtol=1e-5)
diffs = jax.tree.map(lambda a, b: float(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)).max()), ref_p, man_p)
worst = max(jax.tree.leaves(diffs))
assert worst < 5e-2, worst  # bf16 params; identical update within rounding

# algo="auto": the selector resolves an allreduce per payload size at trace
# time; the step must match the reference like the pinned variant does
params_a = decoder.init(key, cfg)
opt_a = adamw.init(params_a, ocfg)
step_auto = manual_step.make_manual_train_step(cfg, tcfg, mesh, topo)
err_a = manual_step.init_error_state(params_a, False)
_, _, _, auto_m = step_auto(params_a, opt_a, err_a, batch)
np.testing.assert_allclose(float(auto_m["loss"]), float(ref_m["loss"]),
                           rtol=1e-5)
from repro.core import runtime as _rt
assert _rt.selection_stats().total > 0, "auto step never hit the selector"

# compressed variant: loss must still go DOWN over a few steps
# (params/opt were donated above -- rebuild fresh copies)
params = decoder.init(key, cfg)
opt = adamw.init(params, ocfg)
step_c = manual_step.make_manual_train_step(cfg, tcfg, mesh, topo,
                                            algo="pip_mcoll",
                                            compress_grads=True)
p2, o2 = params, opt
err = manual_step.init_error_state(params, True)
losses = []
for i in range(6):
    p2, o2, err, m = step_c(p2, o2, err, batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print(f"manual_step_check N={N} P={P}: OK worst_param_diff={worst:.2e} "
      f"compressed_losses={losses[0]:.4f}->{losses[-1]:.4f}")

"""Validate the manual mcoll train step against the pjit reference on a
(node x local) CPU mesh: same loss trajectory, the compressed variant
stays within quantization tolerance, the overlapped (persistent
nonblocking) gradient sync is bit-exact vs its barrier-style twin — in
both its decompositions (backward-segmented layer-wise VJP, the default
where supported, and monolithic) and with per-bucket error-feedback
threading through carry ops under a codec — the error-budget schedule
hook re-resolves plans only at boundaries, and plan rebinds release the
ops they replace (live-op count stays flat under an oscillating
schedule)."""
import sys
N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.topology import Topology
from repro.models import decoder
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.train.step import TrainConfig, train_step
from repro.train import manual_step

cfg = reduced_config("smollm-360m")
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                         schedule="constant", grad_clip=1e9)
tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat="none"))
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)

key = jax.random.PRNGKey(0)
params = decoder.init(key, cfg)
opt = adamw.init(params, ocfg)
B, T = N * P * 2, 32
batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                      cfg.vocab)}

# reference: single-device pjit semantics (global batch)
ref_p, ref_o, ref_m = jax.jit(
    lambda p, o, b: train_step(p, o, b, cfg, tcfg))(params, opt, batch)

# manual mcoll step (pip_mcoll allreduce, per-tensor sync)
step = manual_step.make_manual_train_step(cfg, tcfg, mesh, topo,
                                          algo="pip_mcoll", bucketed=False)
err = manual_step.init_error_state(params)
man_p, man_o, _, man_m = step(params, opt, err, batch)

np.testing.assert_allclose(float(man_m["loss"]), float(ref_m["loss"]),
                           rtol=1e-5)
diffs = jax.tree.map(lambda a, b: float(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)).max()), ref_p, man_p)
worst = max(jax.tree.leaves(diffs))
assert worst < 5e-2, worst  # bf16 params; identical update within rounding

# default step (algo="auto", bucketed): grads flatten into fixed-size
# buckets, one selector-planned allreduce per bucket; must match the
# reference like the pinned variant does
params_a = decoder.init(key, cfg)
opt_a = adamw.init(params_a, ocfg)
step_auto = manual_step.make_manual_train_step(cfg, tcfg, mesh, topo)
err_a = manual_step.init_error_state(params_a)
_, _, _, auto_m = step_auto(params_a, opt_a, err_a, batch)
np.testing.assert_allclose(float(auto_m["loss"]), float(ref_m["loss"]),
                           rtol=1e-5)
from repro.core import runtime as _rt
assert _rt.selection_stats().total > 0, "auto step never hit the selector"

# the default bucket size sits in the pipelined-allreduce regime: gradient
# sync defaults to bucketed pipelined allreduce on this topology
from repro.core import autotune as _at, costmodel as _cm
_sel = _at.default_selector().choose(
    "allreduce", topo, manual_step.DEFAULT_BUCKET_BYTES,
    net=_cm.net_for(topo))
assert _sel.algo == "pip_pipeline", _sel
assert _sel.chunks >= 1, _sel

# bucketed-vs-unbucketed equivalence: same pinned algorithm on both paths
# must be BIT-EXACT (elementwise reductions are bucket-boundary-invariant)
pb = decoder.init(key, cfg)
ob = adamw.init(pb, ocfg)
step_b = manual_step.make_manual_train_step(
    cfg, tcfg, mesh, topo, algo="pip_pipeline", bucketed=True,
    bucket_bytes=256 << 10)  # several buckets for this model
bp, bo, _, bm = step_b(pb, ob, manual_step.init_error_state(pb), batch)
pu = decoder.init(key, cfg)
ou = adamw.init(pu, ocfg)
step_u = manual_step.make_manual_train_step(
    cfg, tcfg, mesh, topo, algo="pip_pipeline", bucketed=False)
up, uo, _, um = step_u(pu, ou, manual_step.init_error_state(pu), batch)
bucket_diffs = jax.tree.map(
    lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                               - b.astype(jnp.float32)).max()), bp, up)
worst_bucket = max(jax.tree.leaves(bucket_diffs))
assert worst_bucket == 0.0, f"bucketed sync not bit-exact: {worst_bucket}"
assert float(bm["loss"]) == float(um["loss"]), (bm["loss"], um["loss"])

# compressed variant (error_budget admits int8_block; error feedback state
# threads per bucket): loss must still go DOWN over a few steps
# (params/opt were donated above -- rebuild fresh copies)
BUDGET = 0.004  # admits int8_block (bound 0.5/127), excludes fp8/topk
params = decoder.init(key, cfg)
opt = adamw.init(params, ocfg)
step_c = manual_step.make_manual_train_step(
    cfg, tcfg, mesh, topo, algo="pip_mcoll", error_budget=BUDGET,
    codec="int8_block", bucket_bytes=256 << 10)
p2, o2 = params, opt
err = manual_step.init_error_state(params, BUDGET, bucket_bytes=256 << 10,
                                   topo=topo)
assert len(err) > 1, "expected multiple per-bucket feedback buffers"
assert err[0].shape[0] == topo.world, "per-device feedback rows"
losses = []
for i in range(6):
    p2, o2, err, m = step_c(p2, o2, err, batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
# feedback buffers must carry non-zero residuals after a compressed step,
# on EVERY device (the state is per-device, sharded — not replicated)
e0 = np.asarray(err[0])
assert all(np.abs(e0[d]).max() > 0 for d in range(topo.world)), \
    "error feedback never engaged on some device"

# --- overlapped gradient sync (persistent nonblocking per-bucket ops) -----
# the overlapped step must be BIT-EXACT vs the barrier-style variant of the
# same decomposition (identical compiled programs, only host scheduling
# differs), and agree with the fused step's loss
from repro.core import runtime as _rt2
po = decoder.init(key, cfg)
oo = adamw.init(po, ocfg)
step_ov = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_pipeline", bucket_bytes=256 << 10,
    overlap=True)
op1, oo1, om1 = step_ov(po, oo, batch)
pb2 = decoder.init(key, cfg)
ob2 = adamw.init(pb2, ocfg)
step_ba = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_pipeline", bucket_bytes=256 << 10,
    overlap=False)
bp1, bo1, bm1 = step_ba(pb2, ob2, batch)
ov_diffs = jax.tree.map(
    lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                               - b.astype(jnp.float32)).max()), op1, bp1)
worst_ov = max(jax.tree.leaves(ov_diffs))
assert worst_ov == 0.0, f"overlapped sync not bit-exact: {worst_ov}"
assert float(om1["loss"]) == float(bm1["loss"]), (om1["loss"], bm1["loss"])
np.testing.assert_allclose(float(om1["loss"]), float(ref_m["loss"]),
                           rtol=1e-5)
# ... and the segmented decomposition's UPDATE agrees with the pjit
# reference within bf16 rounding (its grads differ from the monolithic
# backward only by XLA reduction order, ~2^-11 relative)
seg_ref_diffs = jax.tree.map(lambda a, b: float(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)).max()), ref_p, op1)
worst_seg_ref = max(jax.tree.leaves(seg_ref_diffs))
assert worst_seg_ref < 5e-2, worst_seg_ref
assert len(step_ov.grad_sync.plans()) > 1, "expected multiple buckets"
# persistent ops compile once: further steps add no exec-cache misses
_rt2.cache_stats().reset()
op1, oo1, om1 = step_ov(op1, oo1, batch)
op1, oo1, om1 = step_ov(op1, oo1, batch)
assert _rt2.cache_stats().exec_misses == 0, \
    "overlapped step recompiled after warmup"

# --- adaptive error budget: schedule hook on the persistent grad sync -----
# the per-bucket codec plan re-resolves ONLY when the budget crosses a plan
# boundary: lossless below the threshold step, int8_block at/after it, and
# exactly one op rebuild at the crossing
ps = decoder.init(key, cfg)
os_ = adamw.init(ps, ocfg)
sched = lambda step: 0.0 if step < 2 else BUDGET
step_ad = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_mcoll", error_budget=sched,
    bucket_bytes=256 << 10)
sched_losses = []
for i in range(4):
    ps, os_, ms = step_ad(ps, os_, batch)
    gs = step_ad.grad_sync
    assert gs.budget_at(i) == sched(i)
    if i < 2:
        assert all(p == "pip_mcoll" for p in gs.plans()), (i, gs.plans())
        assert gs.rebuilds == 0, gs.rebuilds
    else:
        assert all(p == "pip_mcoll@int8_block" for p in gs.plans()), \
            (i, gs.plans())
        assert gs.rebuilds == 1, gs.rebuilds  # one transition, no churn
    sched_losses.append(float(ms["loss"]))
assert sched_losses[-1] < sched_losses[0], sched_losses

# --- backward-segmented decomposition ------------------------------------
# the overlapped steps above resolved segmented="auto" -> the layer-wise
# VJP decomposition (decoder family, microbatches=1): bucket i's allreduce
# is in flight while bucket i+1's backward segment computes. The monolithic
# decomposition must still be constructible and agree on the loss (its
# grads differ from segmented only by XLA reduction-order rounding).
assert step_ov.mode == "segmented", step_ov.mode
assert step_ba.mode == "segmented", step_ba.mode
assert len(step_ov.bounds) >= 1, step_ov.bounds
pm = decoder.init(key, cfg)
om_ = adamw.init(pm, ocfg)
step_mono = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_pipeline", bucket_bytes=256 << 10,
    segmented=False)
_, _, mm = step_mono(pm, om_, batch)
assert step_mono.mode == "monolithic", step_mono.mode
np.testing.assert_allclose(float(mm["loss"]), float(ref_m["loss"]),
                           rtol=1e-5)

# segmented + compressed: per-bucket error feedback rides the CARRY ops
# (start(x, carry=err) -> (y, new_err)); the overlap/barrier twins stay
# bit-identical because the threaded state makes each step a pure function
# of (params, opt, errs, batch), identically scheduled either way
pe1 = decoder.init(key, cfg)
oe1 = adamw.init(pe1, ocfg)
step_ef = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_mcoll", error_budget=BUDGET,
    codec="int8_block", bucket_bytes=64 << 10, overlap=True)
pe2 = jax.tree.map(jnp.copy, pe1)
oe2 = jax.tree.map(jnp.copy, oe1)
step_ef_ba = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_mcoll", error_budget=BUDGET,
    codec="int8_block", bucket_bytes=64 << 10, overlap=False)
ef_losses = []
for i in range(3):
    pe1, oe1, me1 = step_ef(pe1, oe1, batch)
    pe2, oe2, me2 = step_ef_ba(pe2, oe2, batch)
    assert float(me1["loss"]) == float(me2["loss"]), (me1, me2)
    ef_losses.append(float(me1["loss"]))
ef_diffs = jax.tree.map(
    lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                               - b.astype(jnp.float32)).max()), pe1, pe2)
worst_ef = max(jax.tree.leaves(ef_diffs))
assert worst_ef == 0.0, f"compressed overlap twins diverged: {worst_ef}"
assert ef_losses[-1] < ef_losses[0], ef_losses
gse = step_ef.grad_sync
assert all(op.carry for op in gse._ops), gse.plans()
assert all(float(jnp.abs(e).max()) > 0 for e in gse.errs), \
    "per-bucket carry feedback never engaged"

# --- rebind hygiene under the REAL resolver ------------------------------
# an oscillating budget schedule crosses a plan boundary every step on this
# topology (pip_mcoll resolves lossless at 0.0, @int8_block at BUDGET);
# every rebuild must release the ops it replaces, so the process-wide
# live-op count stays flat however often the schedule oscillates
from repro.core import comm as _comm_mod
from repro.core.comm import Communicator
gs2 = manual_step.OverlappedGradSync(
    Communicator(mesh, topo), [(0, 65536), (65536, 2 * 65536)],
    metric_len=4, algo="pip_mcoll",  # 256 KiB buckets: the same regime the
    error_budget=lambda s: BUDGET if s % 2 else 0.0)  # sched leg proved
    # resolves lossless at 0.0 and @int8_block at BUDGET
rngp = np.random.default_rng(0)
pay = [jnp.asarray(rngp.standard_normal((topo.world, n)), jnp.float32)
       for _, n in gs2.slices]
mv = jnp.ones((topo.world, 4), jnp.float32)
gs2.ensure_ops(0)
live0 = _comm_mod.live_persistent_ops()
for s in range(8):
    gs2.ensure_ops(s)
    assert _comm_mod.live_persistent_ops() == live0, (s, live0)
    synced, _ = gs2.sync(pay, mv)
    assert all(np.isfinite(np.asarray(x)).all() for x in synced)
assert gs2.rebuilds == 7, gs2.rebuilds
assert gs2.plans() == ["pip_mcoll@int8_block"] * 2, gs2.plans()
assert all(op.carry for op in gs2._ops)

print(f"manual_step_check N={N} P={P}: OK worst_param_diff={worst:.2e} "
      f"bucketed_bitexact_diff={worst_bucket:.1e} "
      f"overlapped_bitexact_diff={worst_ov:.1e} "
      f"segments={len(step_ov.bounds)} "
      f"ef_twin_diff={worst_ef:.1e} "
      f"sched_rebuilds={step_ad.grad_sync.rebuilds} "
      f"osc_rebuilds={gs2.rebuilds} "
      f"compressed_losses={losses[0]:.4f}->{losses[-1]:.4f}")

"""Telemetry acceptance on a real (N, P) CPU mesh.

Usage: telemetry_check.py N P   (run under XLA_FLAGS device_count = N*P)

Asserts:
  1. a segmented-overlapped train step run with the tracer on produces a
     Perfetto-exportable trace whose every backward stage (fwd, head_bwd,
     per-chunk chunk_bwd, embed_bwd, apply) is a span nested inside the
     enclosing train/step window, with the per-bucket allreduce start/wait
     windows on their own bucket:<i> tracks inside the same window (the
     overlap timeline the tentpole promises);
  2. the drift detector flags a poisoned tuning-table row (a fake-fast
     entry that hijacks selection) and ``Selector.ingest`` repairs the
     table from the observed medians so ``choose`` recovers;
  3. the telemetry hooks cost < 2% on the persistent-op hot path when the
     tracer is disabled (stripped-replica baseline, min-of-medians);
  4. ``snapshot()`` unifies cache/selection/live-op observables non-trivially.
"""
import json
import sys
import tempfile

N, P = int(sys.argv[1]), int(sys.argv[2])

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune, runtime, telemetry
from repro.core.comm import Communicator
from repro.core.topology import Topology

mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology.from_mesh(mesh)
comm = Communicator(mesh, topo)
telemetry.enable()

# --- 1. segmented-overlapped train step -> nested spans -------------------
from repro.configs import reduced_config
from repro.models import decoder
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.train import manual_step
from repro.train.step import TrainConfig

M = N * P
cfg = reduced_config("smollm-360m")
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                         schedule="constant", grad_clip=1e9)
tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat="none"))
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (max(M, 2), 32), 0, cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(1),
                                      (max(M, 2), 32), 0, cfg.vocab)}
params = decoder.init(key, cfg)
opt = adamw.init(params, ocfg)
step = manual_step.make_overlapped_train_step(
    cfg, tcfg, mesh, topo, algo="pip_pipeline", bucket_bytes=256 << 10,
    overlap=True, segmented=True)
for _ in range(2):  # compile + settle shardings outside the traced window
    params, opt, m = step(params, opt, batch)
    jax.block_until_ready((params, m["loss"]))
telemetry.reset()  # the trace below covers exactly one steady-state step
params, opt, m = step(params, opt, batch)
jax.block_until_ready((params, m["loss"]))

spans = telemetry.spans()
by_name = {}
for s in spans:
    by_name.setdefault(s.name, []).append(s)
(step_span,) = by_name["train/step"]
n_chunks = len(step.bounds)
stage_names = (["train/fwd", "train/head_bwd"]
               + [f"train/chunk_bwd[{k}]" for k in range(n_chunks)]
               + ["train/embed_bwd", "train/apply"])
for name in stage_names:
    (s,) = by_name[name]
    assert s.track == "main", (name, s.track)
    assert (step_span.start <= s.start
            and s.end <= step_span.end + 1e-9), \
        (name, s.start, s.end, step_span.start, step_span.end)
# per-bucket overlap windows: every bucket span rides its own track and
# lies inside the step window (these ARE the hidden-communication windows)
bucket_spans = [s for s in spans if s.cat == "bucket" and s.duration > 0.0]
n_buckets = len(step.grad_sync.slices)
assert len(bucket_spans) == n_buckets, (len(bucket_spans), n_buckets)
assert len({s.track for s in bucket_spans}) == n_buckets
for s in bucket_spans:
    assert s.track.startswith("bucket:"), s.track
    assert (step_span.start <= s.start
            and s.end <= step_span.end + 1e-9), (s.name, s.track)
    tags = dict(s.args)
    assert tags["collective"] == "allreduce" and tags["algo"], tags

# Perfetto export round-trip: named tracks + the same nesting by tid
with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
    trace = telemetry.export_chrome_trace(f.name)
    loaded = json.load(open(f.name))
assert loaded == trace
names = {e["args"]["name"] for e in loaded["traceEvents"]
         if e["ph"] == "M"}
assert "main" in names and any(n.startswith("bucket:") for n in names)
evs = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
assert {e["name"] for e in evs} >= set(stage_names) | {"train/step"}
assert all(e["ts"] >= 0.0 and e["dur"] >= 0.0 for e in evs)

# --- 2. drift detector flags a poisoned row; ingest repairs it ------------
telemetry.reset()
nbytes = 4096
comm.calibrate(names=("allreduce",), sizes=(nbytes,), iters=4)
sel = comm.selector
good = sel.choose("allreduce", topo, nbytes)
good_plan = autotune.encode_plan(good.algo, good.chunks, good.codec)
entry = sel.table.lookup(topo, "allreduce", "float32", nbytes)
# victim must be a lossless plan: choose() under the default zero error
# budget never admits codec plans, poisoned or not
victim = sorted(p for p in entry
                if p != good_plan
                and autotune.decode_plan(p)[2] == "none")[0]
# poison: a fake-fast table row hijacks selection toward the victim plan
sel.table.record(topo, "allreduce", "float32", nbytes, victim, 1e-9)
hijacked = sel.choose("allreduce", topo, nbytes)
assert autotune.encode_plan(hijacked.algo, hijacked.chunks,
                            hijacked.codec) == victim, hijacked
flagged = telemetry.drifted_plans(selector=sel)
assert any(r.plan == victim and r.collective == "allreduce"
           for r in flagged), flagged
victim_row = next(r for r in flagged if r.plan == victim)
assert victim_row.table_s == 1e-9 and victim_row.drift_vs_table > 0.5
# ingest folds the observed medians back in: the poisoned row is repaired
# and selection recovers without re-running calibration
n_ingested = sel.ingest(min_samples=2)
assert n_ingested >= len(entry), n_ingested
repaired = sel.choose("allreduce", topo, nbytes)
assert autotune.encode_plan(repaired.algo, repaired.chunks,
                            repaired.codec) == good_plan, repaired
assert not any(r.plan == victim
               for r in telemetry.drifted_plans(selector=sel))

# --- 3. disabled-path overhead guard: the telemetry hooks left in the
# persistent-op hot path (an enabled() read in start, a None-token check in
# wait) must cost < 2% of a start/wait round trip when telemetry is off.
#
# Measured in two parts because an end-to-end A/B subtraction cannot
# resolve 2% here: an A/A control (timing the SAME function in both slots
# of a pairwise-interleaved loop) shows a +-2-3% noise floor on this
# 8-thread-device CPU target, i.e. the round trip's run-to-run variance
# swamps the quantity under test. So:
#   (a) the precise bound times the exact instructions the disabled path
#       adds, amortized over a tight loop (deterministic to ~ns), against
#       the measured round trip — this is the <2% assertion;
#   (b) an interleaved end-to-end A/B keeps a loose sanity bound (<15%,
#       above the noise floor) so a gross regression — e.g. an always-on
#       perf_counter or observe_plan landing in the disabled path — still
#       fails the check even if it hides from the enumerated-hook loop.
telemetry.disable()
import time as _time

op = comm.allreduce_init(shape=(M, 1 << 14), dtype=jnp.float32,
                         algo="pip_pipeline")
xb = jnp.ones((M, 1 << 14), jnp.float32)
op.start(xb).wait()  # warm the executable


def instrumented_once():
    op.start(xb).wait(block=True)


def stripped_once():
    # start()+wait(block=True) minus the telemetry lines — the baseline a
    # hypothetical hook-free build would run
    x2 = op._check_operand(xb)
    op._inflight += 1
    op.starts += 1
    v = op._compiled(x2)
    op._inflight -= 1
    jax.block_until_ready(v)


def hook_lines_once():
    # exactly what telemetry adds to a disabled start/wait round trip: the
    # enabled() read in start, the (token, t0) defaults, and the None-token
    # check in wait
    if telemetry.enabled():
        raise AssertionError("telemetry must be disabled here")
    token, t0 = None, 0.0
    if token is not None:
        raise AssertionError
    return t0


HOOK_REPS = 200_000
t0 = _time.perf_counter()
for _ in range(HOOK_REPS):
    hook_lines_once()
hook_s = (_time.perf_counter() - t0) / HOOK_REPS

# round trip: block-averaged so per-call scheduling noise amortizes
RT_BLOCK, rt = 50, []
for _ in range(6):
    t0 = _time.perf_counter()
    for _ in range(RT_BLOCK):
        instrumented_once()
    rt.append((_time.perf_counter() - t0) / RT_BLOCK)
rt_s = sorted(rt)[len(rt) // 2]

overhead = hook_s / rt_s
assert overhead < 0.02, \
    f"disabled-telemetry dispatch overhead {overhead:.2%} " \
    f"(hooks {hook_s * 1e9:.0f}ns vs round trip {rt_s * 1e6:.1f}us)"

# (b) end-to-end sanity: interleaved A/B with a bound above the measured
# noise floor
inst_s, strip_s = [], []
for r in range(200):
    t0 = _time.perf_counter()
    (stripped_once if r % 2 else instrumented_once)()
    t1 = _time.perf_counter()
    (instrumented_once if r % 2 else stripped_once)()
    t2 = _time.perf_counter()
    (strip_s if r % 2 else inst_s).append(t1 - t0)
    (inst_s if r % 2 else strip_s).append(t2 - t1)
inst_med = sorted(inst_s)[len(inst_s) // 2]
strip_med = sorted(strip_s)[len(strip_s) // 2]
e2e = (inst_med - strip_med) / strip_med
assert e2e < 0.15, \
    f"end-to-end disabled-telemetry overhead {e2e:.2%} " \
    f"({inst_med * 1e6:.1f}us vs {strip_med * 1e6:.1f}us) — far above " \
    f"the hook-level bound; something heavy runs on the disabled path"
telemetry.enable()

# --- 4. unified snapshot --------------------------------------------------
snap = telemetry.snapshot()
assert snap["enabled"] and snap["tracer"]["spans"] > 0
assert snap["cache"]["exec_hits"] > 0
assert snap["selection"]["total"] > 0 and snap["selection"]["by_choice"]
assert any(p["collective"] == "allreduce" and p["samples"] >= 2
           for p in snap["plans"])
assert snap["histograms"], "registry never observed a latency"

print(f"telemetry_check N={N} P={P}: OK spans={len(spans)} "
      f"buckets={n_buckets} chunks={n_chunks} victim={victim} "
      f"ingested={n_ingested} good={good_plan}")

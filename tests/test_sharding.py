"""Sharding rules: divisibility-aware spec construction + logical trees
matching param trees for every architecture."""
import jax
import pytest
from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import decoder, encdec
from repro.sharding.rules import Rules, spec_for

MESH_SHAPE = {"pod": 2, "data": 16, "model": 16}
RULES = Rules(batch=("pod", "data"), fsdp=("data",), tp="model")


def test_spec_divisible():
    s = spec_for(("fsdp", "heads"), (4096, 64), RULES, MESH_SHAPE)
    assert s == P("data", "model")


def test_spec_replicates_when_indivisible():
    # 15 heads don't divide model=16 -> replicate that dim
    s = spec_for(("fsdp", "heads"), (960, 15), RULES, MESH_SHAPE)
    assert s == P("data", None)
    # 7-dim fsdp falls back too
    s = spec_for(("fsdp",), (7,), RULES, MESH_SHAPE)
    assert s == P(None)


def test_batch_axes_compose():
    s = spec_for(("batch", None), (256, 4096), RULES, MESH_SHAPE)
    assert s == P(("pod", "data"), None)
    # batch 3 can't take pod*data=32
    s = spec_for(("batch", None), (3, 16), RULES, MESH_SHAPE)
    assert s == P(None, None)


@given(dim=st.integers(1, 4096))
@settings(max_examples=100, deadline=None)
def test_spec_never_invalid(dim):
    """Whatever the dim, the spec must keep shard counts dividing the dim."""
    s = spec_for(("heads",), (dim,), RULES, MESH_SHAPE)
    if s[0] is not None:
        assert dim % MESH_SHAPE["model"] == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_logical_tree_matches_param_tree(arch):
    """logical(cfg) must have exactly the param-tree structure (guards
    against drift between init() and logical())."""
    cfg = reduced_config(arch)
    api = encdec if cfg.family == "encdec" else decoder
    shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                            jax.random.PRNGKey(0))
    logical = api.logical(cfg)
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    ls = jax.tree.structure(logical, is_leaf=is_leaf)
    ps = jax.tree.structure(shapes)
    assert ls == ps, f"{arch}: logical tree != param tree"
    # every logical tuple's rank matches its param's rank (stacked +1)
    llist = jax.tree.leaves(logical, is_leaf=is_leaf)
    plist = jax.tree.leaves(shapes)
    for lg, sh in zip(llist, plist):
        assert len(lg) == len(sh.shape), (arch, lg, sh.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_specs_build(arch):
    """Building NamedShardings for the FULL config on an abstract production
    mesh must succeed for every arch (no divisibility crashes)."""
    from repro.launch import specs as S
    cfg = get_config(arch)
    devices = jax.devices() * 0 or None
    # abstract mesh: reuse the real 1-device mesh but with production shape
    # arithmetic exercised through spec_for directly
    api = encdec if cfg.family == "encdec" else decoder
    logical = api.logical(cfg)
    shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                            jax.random.PRNGKey(0))
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    rules = Rules(batch=("pod", "data"), fsdp=("data",), tp="model")
    specs_tree = jax.tree.map(
        lambda lg, sh: spec_for(lg, sh.shape, rules, MESH_SHAPE),
        logical, shapes, is_leaf=is_leaf)
    n_sharded = sum(1 for s in jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P))
        if any(e is not None for e in s))
    assert n_sharded > 0, arch

"""Collective conformance suite: every registered (collective x algorithm)
pair against the ``xla_*`` reference, across dtypes, odd / non-power-of-two
payload shapes, and chunk counts.

Unlike the subprocess checks (tests/checks/*), this suite runs IN-PROCESS
on whatever devices the interpreter was started with, factoring
``jax.device_count()`` into a (node, local) mesh. Under the tier-1 run
that is the 1-device degenerate topology (cheap, still exercises every
algorithm's trace path and the chunking/padding arithmetic); CI runs the
same suite under a device-count matrix
(``XLA_FLAGS=--xla_force_host_platform_device_count={1,2,8}``) so the
multi-device routing is conformance-tested per count. The exhaustive
dtype/shape/chunk sweeps are marked ``slow`` so the matrix can split fast
and slow legs.

Property sweeps use ``_hypothesis_compat``: full property search with
hypothesis installed, a fixed deterministic replay without it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import autotune, compress, costmodel, mcoll, runtime
from repro.core.comm import Communicator
from repro.core.topology import Topology

# ---------------------------------------------------------------------------
# mesh from the ambient device count (the CI matrix sets XLA_FLAGS)
# ---------------------------------------------------------------------------

DC = jax.device_count()
P = 2 if DC % 2 == 0 else 1
N = DC // P
M = N * P
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)
COMM = Communicator(mesh, topo)

PAIRS = [(coll, algo) for coll in runtime.collectives()
         for algo in mcoll.algorithms(coll)]
CHUNKED_PAIRS = [(coll, algo) for coll, algo in PAIRS
                 if mcoll.supports_chunks(coll, algo)]
CODEC_PAIRS = [(coll, algo) for coll, algo in PAIRS
               if mcoll.supports_codec(coll, algo)]
# every (collective x codec) pair, through each codec-capable algorithm
CODEC_TRIPLES = [(coll, algo, cd) for coll, algo in CODEC_PAIRS
                 for cd in compress.lossy()]
DTYPES = ("float32", "bfloat16", "int32")

# reference algorithm per collective: the vendor lowering ("linear" is
# scatter's vendor-equivalent masked select)
REF = {coll: ("xla" if "xla" in mcoll.algorithms(coll) else "linear")
       for coll in runtime.collectives()}


def _operand(coll: str, m: int, dtype: str):
    """Global operand with per-rank payload ``m`` elements. Values are
    small integers so every reduction is exact in every swept dtype
    (bf16 represents ints < 256 exactly) and equality checks can be
    bitwise across algorithms."""
    dt = jnp.dtype(dtype)
    if coll == "allgather" or coll == "scatter":
        return (jnp.arange(M * m) % 97).astype(dt)
    if coll == "broadcast":
        return (jnp.arange(m) % 97 + 1).astype(dt)
    if coll == "allreduce":
        return (jnp.arange(M * m) % 5).astype(dt).reshape(M, m)
    if coll == "reduce_scatter":
        return (jnp.arange(M * M * m) % 5).astype(dt).reshape(M, M * m)
    if coll == "alltoall":
        return (jnp.arange(M * M * m) % 97).astype(dt).reshape(M, M, m)
    raise ValueError(coll)


def _oracle(coll: str, x):
    """Pure-numpy semantics of each collective on the global operand."""
    a = np.asarray(x.astype(jnp.float32))
    if coll == "allgather":
        return np.stack([a] * M)          # row d = full gather on device d
    if coll == "scatter":
        return a                           # shards concatenate to the input
    if coll == "broadcast":
        return np.stack([a] * M)
    if coll == "allreduce":
        return np.stack([a.sum(0)] * M)
    if coll == "reduce_scatter":
        return a.sum(0)
    if coll == "alltoall":
        return a.transpose(1, 0, 2)
    raise ValueError(coll)


def _feasible(coll: str, algo: str) -> bool:
    return algo in autotune.candidates(coll, topo)


def _run(coll: str, algo: str, x, **kw):
    out = COMM.invoke(coll, x, algo=algo, **kw)
    return np.asarray(out.astype(jnp.float32))


def _run_persistent(coll: str, algo: str, x, **kw):
    """The same plan through a persistent op: init (plan resolved +
    compiled once), one start/wait."""
    op = COMM.persistent(coll, x, algo=algo, **kw)
    return np.asarray(op.start(x).wait().astype(jnp.float32))


def _assert_conforms(coll: str, algo: str, m: int, dtype: str, **kw):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, m, dtype)
    got = _run(coll, algo, x, **kw)
    ref = _run(coll, REF[coll], x)
    # integer-valued payloads: every algorithm must agree with the vendor
    # reference bitwise, in every dtype
    np.testing.assert_array_equal(
        got, ref, err_msg=f"{coll}/{algo} m={m} {dtype} {kw}")
    np.testing.assert_array_equal(
        ref, _oracle(coll, x), err_msg=f"{coll}/{REF[coll]} oracle m={m}")


# ---------------------------------------------------------------------------
# fast leg: every registered pair, f32, odd payload (runs at every device
# count in the CI matrix; 1-device under tier-1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll,algo", PAIRS)
def test_conformance_every_pair_odd_payload(coll, algo):
    _assert_conforms(coll, algo, 5, "float32")


@pytest.mark.parametrize("coll,algo", CHUNKED_PAIRS)
def test_conformance_chunked_pairs_basic(coll, algo):
    # a chunk count that does not divide the payload (remainder segment)
    _assert_conforms(coll, algo, 5, "float32", chunks=2)
    _assert_conforms(coll, algo, 5, "float32", chunks=3)


# ---------------------------------------------------------------------------
# persistent leg: blocking vs persistent-nonblocking execution of ONE plan
# must be bitwise identical, for every (collective x algorithm x chunks x
# codec) plan; plus handle-misuse errors (double wait, start past depth)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll,algo", PAIRS)
def test_persistent_matches_blocking_every_pair(coll, algo):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, 5, "float32")
    np.testing.assert_array_equal(_run_persistent(coll, algo, x),
                                  _run(coll, algo, x),
                                  err_msg=f"{coll}/{algo} persistent")


@pytest.mark.parametrize("coll,algo", CHUNKED_PAIRS)
def test_persistent_matches_blocking_chunked(coll, algo):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, 5, "float32")
    for chunks in (2, 3):
        np.testing.assert_array_equal(
            _run_persistent(coll, algo, x, chunks=chunks),
            _run(coll, algo, x, chunks=chunks),
            err_msg=f"{coll}/{algo} c={chunks} persistent")


@pytest.mark.parametrize("coll,algo,cd", CODEC_TRIPLES)
def test_persistent_matches_blocking_compressed(coll, algo, cd):
    """Lossy plans too: same compiled plan, deterministic execution —
    persistent start/wait must reproduce the blocking result bitwise."""
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, 80, "float32")
    np.testing.assert_array_equal(_run_persistent(coll, algo, x, codec=cd),
                                  _run(coll, algo, x, codec=cd),
                                  err_msg=f"{coll}/{algo}@{cd} persistent")


@pytest.mark.parametrize("coll", sorted(runtime.collectives()))
def test_persistent_auto_plan_matches_blocking(coll):
    """algo="auto" resolves to the same plan at init and call time — the
    persistent op and the blocking method share one executable."""
    x = _operand(coll, 5, "float32")
    np.testing.assert_array_equal(_run_persistent(coll, "auto", x),
                                  _run(coll, "auto", x))


def test_persistent_compiles_once_across_starts():
    """Repeated start/wait on one op never re-enters the exec cache."""
    x = _operand("allreduce", 16, "float32")
    op = COMM.allreduce_init(x, algo="pip_mcoll")
    misses0 = runtime.cache_stats().exec_misses
    outs = [np.asarray(op.start(x).wait()) for _ in range(4)]
    assert runtime.cache_stats().exec_misses == misses0
    assert op.starts == 4
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
    # a second op of the same spec is an exec-cache hit, not a compile
    COMM.allreduce_init(x, algo="pip_mcoll")
    assert runtime.cache_stats().exec_misses == misses0


def test_persistent_handle_misuse_errors():
    x = _operand("allreduce", 8, "float32")
    op = COMM.allreduce_init(x, algo="pip_mcoll")  # depth=1
    h = op.start(x)
    with pytest.raises(RuntimeError, match="outstanding"):
        op.start(x)  # start before wait without double buffering
    h.wait()
    with pytest.raises(RuntimeError, match="double wait"):
        h.wait()
    op.start(x).wait()  # slot released: pairing works again
    # depth=2 (double buffering) allows exactly one extra outstanding start
    op2 = COMM.allreduce_init(x, algo="pip_mcoll", depth=2)
    h1, h2 = op2.start(x), op2.start(x)
    with pytest.raises(RuntimeError, match="outstanding"):
        op2.start(x)
    np.testing.assert_array_equal(np.asarray(h1.wait()),
                                  np.asarray(h2.wait()))


def test_persistent_rejects_operand_spec_mismatch():
    x = _operand("allreduce", 8, "float32")
    op = COMM.allreduce_init(x, algo="pip_mcoll")
    with pytest.raises(ValueError, match="compiled for"):
        op.start(_operand("allreduce", 9, "float32"))
    with pytest.raises(ValueError, match="compiled for"):
        op.start(_operand("allreduce", 8, "int32"))


# ---------------------------------------------------------------------------
# compressed leg: every (collective x codec) pair vs the xla reference,
# asserting the codec's stated relative-error bound instead of equality
# (CI runs this as its own matrix step via ``-k compressed``)
# ---------------------------------------------------------------------------


def _assert_conforms_compressed(coll: str, algo: str, cd: str, m: int,
                                **kw):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, m, "float32")
    got = _run(coll, algo, x, codec=cd, **kw)
    ref = _run(coll, REF[coll], x)
    tol = compress.collective_tolerance(cd, coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    err = np.abs(got - ref).max()
    assert err <= tol, f"{coll}/{algo}@{cd} m={m} {kw}: {err} > {tol}"


@pytest.mark.parametrize("coll,algo,cd", CODEC_TRIPLES)
def test_conformance_compressed_pairs(coll, algo, cd):
    _assert_conforms_compressed(coll, algo, cd, 80)


@pytest.mark.parametrize("coll,algo", CODEC_PAIRS)
def test_conformance_compressed_none_is_bitwise(coll, algo):
    """codec="none" on a codec-capable algorithm is the lossless algorithm
    exactly — one plan, bitwise equal to the bare call."""
    x = _operand(coll, 5, "float32")
    np.testing.assert_array_equal(_run(coll, algo, x, codec="none"),
                                  _run(coll, algo, x))


@pytest.mark.parametrize(
    "coll,algo", [(c, a) for c, a in CODEC_PAIRS
                  if mcoll.supports_chunks(c, a)])
def test_conformance_compressed_chunked_compose(coll, algo):
    """codec composes with chunks: compressed segments pipeline
    independently and still land inside the codec bound."""
    _assert_conforms_compressed(coll, algo, "int8_block", 80, chunks=3)


@pytest.mark.parametrize("coll", sorted({c for c, _ in CODEC_PAIRS}))
def test_conformance_compressed_auto_budget(coll):
    """algo="auto" under an error budget resolves to a plan (lossless or
    admissible codec) that conforms within the loosest admissible bound."""
    budget = float(compress.meta("int8_block").error_bound)
    x = _operand(coll, 64, "float32")
    got = _run(coll, "auto", x, error_budget=budget)
    ref = _run(coll, REF[coll], x)
    tol = compress.collective_tolerance("int8_block", coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    assert np.abs(got - ref).max() <= tol


def test_compressed_rejects_integer_payloads():
    """Lossy codecs on integer payloads must fail clearly at trace time,
    not silently round token ids (checked before the degenerate-topology
    shortcut, so the error does not depend on the device count)."""
    x = _operand("allreduce", 5, "int32")
    with pytest.raises(ValueError, match="integer payload"):
        _run("allreduce", "pip_mcoll", x, codec="int8_block")
    # ... while auto under a budget resolves integer payloads lossless
    # instead of crashing, and stays exact
    got = _run("allreduce", "auto", x, error_budget=1.0)
    np.testing.assert_array_equal(got, _run("allreduce", REF["allreduce"],
                                            x))


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo,cd", CODEC_TRIPLES)
@given(m=st.sampled_from([1, 7, 64, 300]))
@settings(max_examples=4, deadline=None)
def test_conformance_compressed_shape_sweep(coll, algo, cd, m):
    """Odd / non-block-divisible payloads through every codec pair."""
    _assert_conforms_compressed(coll, algo, cd, m)


@pytest.mark.parametrize("coll", ("allreduce", "reduce_scatter"))
def test_conformance_compressed_multidim_payload(coll):
    """Compressed reductions accept trailing payload dims like their
    lossless forms ('(M*s, ...)' input), flattening row-major internally."""
    if coll == "allreduce":
        x = (jnp.arange(M * 10 * 3) % 5).astype(jnp.float32).reshape(
            M, 10, 3)
    else:
        x = (jnp.arange(M * M * 4 * 3) % 5).astype(jnp.float32).reshape(
            M, M * 4, 3)
    got = _run(coll, "pip_mcoll", x, codec="int8_block")
    ref = _run(coll, REF[coll], x)
    assert got.shape == ref.shape
    tol = compress.collective_tolerance("int8_block", coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    assert np.abs(got - ref).max() <= tol


# ---------------------------------------------------------------------------
# slow legs: dtype x odd-shape sweep, chunk-count sweep, auto-plan sweep
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo", PAIRS)
@given(m=st.sampled_from([1, 3, 6, 7]), dtype=st.sampled_from(DTYPES))
@settings(max_examples=8, deadline=None)
def test_conformance_dtype_shape_sweep(coll, algo, m, dtype):
    _assert_conforms(coll, algo, m, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo", CHUNKED_PAIRS)
@given(m=st.sampled_from([1, 4, 7]), chunks=st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_conformance_chunk_sweep(coll, algo, m, chunks):
    # chunk counts beyond the payload clamp internally; remainder segments
    # must round-trip exactly (zero padding never leaks into results)
    _assert_conforms(coll, algo, m, "float32", chunks=chunks)


@pytest.mark.slow
@pytest.mark.parametrize("coll", sorted(runtime.collectives()))
@given(m=st.sampled_from([1, 5, 64]), dtype=st.sampled_from(DTYPES))
@settings(max_examples=6, deadline=None)
def test_conformance_auto_plan(coll, m, dtype):
    """algo="auto" resolves an (algo, chunks) plan that conforms too."""
    x = _operand(coll, m, dtype)
    got = _run(coll, "auto", x)
    ref = _run(coll, REF[coll], x)
    np.testing.assert_array_equal(got, ref,
                                  err_msg=f"{coll}/auto m={m} {dtype}")


# ---------------------------------------------------------------------------
# pure-logic properties: chunk planning math (no devices involved)
# ---------------------------------------------------------------------------


@given(rounds=st.integers(2, 512), nbytes=st.integers(64, 1 << 26))
@settings(max_examples=60, deadline=None)
def test_optimal_pipeline_chunks_is_local_minimum(rounds, nbytes):
    """The analytic c* beats its integer neighbors under the stage model
    (C + B/c·beta)(rounds + c − 1)."""
    alpha, beta = 1.0e-6, 1 / 2.5e10
    c = costmodel.optimal_pipeline_chunks(alpha, nbytes, beta, rounds)
    t = costmodel.pipeline_time(alpha, nbytes, beta, rounds, c)
    assert 1 <= c <= costmodel.MAX_CHUNKS
    if c > 1:
        assert t <= costmodel.pipeline_time(alpha, nbytes, beta, rounds,
                                            c - 1) * (1 + 1e-12)
    if c < costmodel.MAX_CHUNKS:
        assert t <= costmodel.pipeline_time(alpha, nbytes, beta, rounds,
                                            c + 1) * (1 + 1e-12)


@given(nbytes=st.sampled_from([256, 4096, 1 << 16, 1 << 20, 1 << 24]))
@settings(max_examples=10, deadline=None)
def test_pipeline_crossover_vs_unchunked(nbytes):
    """The cost model must show the pipelining crossover: chunking never
    helps the latency regime, and wins the bandwidth regime."""
    t16 = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    net = costmodel.net_for(t16)
    c = costmodel.optimal_chunks("allreduce", "pip_pipeline", t16, nbytes,
                                 net)
    t1 = costmodel.allreduce_cost("pip_pipeline", t16, nbytes, net,
                                  chunks=1).time
    tc = costmodel.allreduce_cost("pip_pipeline", t16, nbytes, net,
                                  chunks=c).time
    assert tc <= t1 * (1 + 1e-12)
    if nbytes >= 1 << 20:
        assert c > 1 and tc < t1, (nbytes, c)
    if nbytes <= 256:
        assert c == 1


def test_scatter_rejects_non_divisible_payload():
    """Regression: a payload that cannot shard evenly used to silently
    truncate (dim0 // world); it must be a clear error instead."""
    if M == 1:
        pytest.skip("every payload divides on 1 device")
    x = jnp.arange(float(M * 3 + 1))
    with pytest.raises(ValueError, match="divisible by world"):
        COMM.scatter(x, algo="pip_mcoll")


def test_plan_encode_decode_round_trip():
    assert autotune.encode_plan("pip_pipeline", 1) == "pip_pipeline"
    assert autotune.encode_plan("pip_pipeline", 8) == "pip_pipeline#c8"
    assert autotune.encode_plan("pip_pipeline", 8, "int8_block") == \
        "pip_pipeline#c8@int8_block"
    assert autotune.encode_plan("pip_mcoll", 1, "topk") == "pip_mcoll@topk"
    assert autotune.decode_plan("pip_pipeline#c8") == \
        ("pip_pipeline", 8, "none")
    assert autotune.decode_plan("pip_pipeline#c8@int8_block") == \
        ("pip_pipeline", 8, "int8_block")
    assert autotune.decode_plan("pip_mcoll@fp8_sim") == \
        ("pip_mcoll", 1, "fp8_sim")
    assert autotune.decode_plan("ring") == ("ring", 1, "none")


def test_plans_cover_registry_with_chunk_and_codec_variants():
    t = Topology(4, 4, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    for coll in runtime.collectives():
        ps = autotune.plans(coll, t, 1 << 20)
        algos = {a for a, _, _ in ps}
        assert algos == set(autotune.candidates(coll, t))
        for a, c, cd in ps:
            assert c >= 1
            if c > 1:
                assert mcoll.supports_chunks(coll, a)
            if cd != "none":
                assert mcoll.supports_codec(coll, a)
        # every chunk-capable algorithm gets at least one chunked variant
        # at a bandwidth-regime size
        for a in algos:
            if mcoll.supports_chunks(coll, a):
                assert any(c > 1 for aa, c, _ in ps if aa == a), (coll, a)
        # every codec-capable algorithm gets every lossy codec variant
        for a in algos:
            if mcoll.supports_codec(coll, a):
                planned = {cd for aa, _, cd in ps if aa == a}
                assert set(compress.lossy()) <= planned, (coll, a)

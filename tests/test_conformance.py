"""Collective conformance suite: every registered (collective x algorithm)
pair against the ``xla_*`` reference, across dtypes, odd / non-power-of-two
payload shapes, and chunk counts.

Unlike the subprocess checks (tests/checks/*), this suite runs IN-PROCESS
on whatever devices the interpreter was started with, factoring
``jax.device_count()`` into a (node, local) mesh. Under the tier-1 run
that is the 1-device degenerate topology (cheap, still exercises every
algorithm's trace path and the chunking/padding arithmetic); CI runs the
same suite under a device-count matrix
(``XLA_FLAGS=--xla_force_host_platform_device_count={1,2,8}``) so the
multi-device routing is conformance-tested per count. The exhaustive
dtype/shape/chunk sweeps are marked ``slow`` so the matrix can split fast
and slow legs; the ``comm.split()`` group leg (every collective x
algorithm over the mesh split each way, bitwise against the reference
restricted to the group) selects with ``-k group``.

Property sweeps use ``_hypothesis_compat``: full property search with
hypothesis installed, a fixed deterministic replay without it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import autotune, compress, costmodel, mcoll, runtime
from repro.core.comm import Communicator
from repro.core.topology import Topology

# ---------------------------------------------------------------------------
# mesh from the ambient device count (the CI matrix sets XLA_FLAGS)
# ---------------------------------------------------------------------------

DC = jax.device_count()
P = 2 if DC % 2 == 0 else 1
N = DC // P
M = N * P
mesh = jax.make_mesh((N, P), ("node", "local"))
topo = Topology(N, P)
COMM = Communicator(mesh, topo)

PAIRS = [(coll, algo) for coll in runtime.collectives()
         for algo in mcoll.algorithms(coll)]
CHUNKED_PAIRS = [(coll, algo) for coll, algo in PAIRS
                 if mcoll.supports_chunks(coll, algo)]
CODEC_PAIRS = [(coll, algo) for coll, algo in PAIRS
               if mcoll.supports_codec(coll, algo)]
# every (collective x codec) pair, through each codec-capable algorithm
CODEC_TRIPLES = [(coll, algo, cd) for coll, algo in CODEC_PAIRS
                 for cd in compress.lossy()]
DTYPES = ("float32", "bfloat16", "int32")

# reference algorithm per collective: the vendor lowering ("linear" is
# scatter's vendor-equivalent masked select)
REF = {coll: ("xla" if "xla" in mcoll.algorithms(coll) else "linear")
       for coll in runtime.collectives()}


def _operand(coll: str, m: int, dtype: str):
    """Global operand with per-rank payload ``m`` elements. Values are
    small integers so every reduction is exact in every swept dtype
    (bf16 represents ints < 256 exactly) and equality checks can be
    bitwise across algorithms."""
    dt = jnp.dtype(dtype)
    if coll == "allgather" or coll == "scatter":
        return (jnp.arange(M * m) % 97).astype(dt)
    if coll == "broadcast":
        return (jnp.arange(m) % 97 + 1).astype(dt)
    if coll == "allreduce":
        return (jnp.arange(M * m) % 5).astype(dt).reshape(M, m)
    if coll == "reduce_scatter":
        return (jnp.arange(M * M * m) % 5).astype(dt).reshape(M, M * m)
    if coll == "alltoall":
        return (jnp.arange(M * M * m) % 97).astype(dt).reshape(M, M, m)
    raise ValueError(coll)


def _oracle(coll: str, x):
    """Pure-numpy semantics of each collective on the global operand."""
    a = np.asarray(x.astype(jnp.float32))
    if coll == "allgather":
        return np.stack([a] * M)          # row d = full gather on device d
    if coll == "scatter":
        return a                           # shards concatenate to the input
    if coll == "broadcast":
        return np.stack([a] * M)
    if coll == "allreduce":
        return np.stack([a.sum(0)] * M)
    if coll == "reduce_scatter":
        return a.sum(0)
    if coll == "alltoall":
        return a.transpose(1, 0, 2)
    raise ValueError(coll)


def _feasible(coll: str, algo: str) -> bool:
    return algo in autotune.candidates(coll, topo)


def _run(coll: str, algo: str, x, **kw):
    out = COMM.invoke(coll, x, algo=algo, **kw)
    return np.asarray(out.astype(jnp.float32))


def _run_persistent(coll: str, algo: str, x, **kw):
    """The same plan through a persistent op: init (plan resolved +
    compiled once), one start/wait."""
    op = COMM.persistent(coll, x, algo=algo, **kw)
    return np.asarray(op.start(x).wait().astype(jnp.float32))


def _assert_conforms(coll: str, algo: str, m: int, dtype: str, **kw):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, m, dtype)
    got = _run(coll, algo, x, **kw)
    ref = _run(coll, REF[coll], x)
    # integer-valued payloads: every algorithm must agree with the vendor
    # reference bitwise, in every dtype
    np.testing.assert_array_equal(
        got, ref, err_msg=f"{coll}/{algo} m={m} {dtype} {kw}")
    np.testing.assert_array_equal(
        ref, _oracle(coll, x), err_msg=f"{coll}/{REF[coll]} oracle m={m}")


# ---------------------------------------------------------------------------
# fast leg: every registered pair, f32, odd payload (runs at every device
# count in the CI matrix; 1-device under tier-1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll,algo", PAIRS)
def test_conformance_every_pair_odd_payload(coll, algo):
    _assert_conforms(coll, algo, 5, "float32")


@pytest.mark.parametrize("coll,algo", CHUNKED_PAIRS)
def test_conformance_chunked_pairs_basic(coll, algo):
    # a chunk count that does not divide the payload (remainder segment)
    _assert_conforms(coll, algo, 5, "float32", chunks=2)
    _assert_conforms(coll, algo, 5, "float32", chunks=3)


# ---------------------------------------------------------------------------
# persistent leg: blocking vs persistent-nonblocking execution of ONE plan
# must be bitwise identical, for every (collective x algorithm x chunks x
# codec) plan; plus handle-misuse errors (double wait, start past depth)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll,algo", PAIRS)
def test_persistent_matches_blocking_every_pair(coll, algo):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, 5, "float32")
    np.testing.assert_array_equal(_run_persistent(coll, algo, x),
                                  _run(coll, algo, x),
                                  err_msg=f"{coll}/{algo} persistent")


@pytest.mark.parametrize("coll,algo", CHUNKED_PAIRS)
def test_persistent_matches_blocking_chunked(coll, algo):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, 5, "float32")
    for chunks in (2, 3):
        np.testing.assert_array_equal(
            _run_persistent(coll, algo, x, chunks=chunks),
            _run(coll, algo, x, chunks=chunks),
            err_msg=f"{coll}/{algo} c={chunks} persistent")


@pytest.mark.parametrize("coll,algo,cd", CODEC_TRIPLES)
def test_persistent_matches_blocking_compressed(coll, algo, cd):
    """Lossy plans too: same compiled plan, deterministic execution —
    persistent start/wait must reproduce the blocking result bitwise."""
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, 80, "float32")
    np.testing.assert_array_equal(_run_persistent(coll, algo, x, codec=cd),
                                  _run(coll, algo, x, codec=cd),
                                  err_msg=f"{coll}/{algo}@{cd} persistent")


@pytest.mark.parametrize("coll", sorted(runtime.collectives()))
def test_persistent_auto_plan_matches_blocking(coll):
    """algo="auto" resolves to the same plan at init and call time — the
    persistent op and the blocking method share one executable."""
    x = _operand(coll, 5, "float32")
    np.testing.assert_array_equal(_run_persistent(coll, "auto", x),
                                  _run(coll, "auto", x))


def test_persistent_compiles_once_across_starts():
    """Repeated start/wait on one op never re-enters the exec cache."""
    x = _operand("allreduce", 16, "float32")
    op = COMM.allreduce_init(x, algo="pip_mcoll")
    misses0 = runtime.cache_stats().exec_misses
    outs = [np.asarray(op.start(x).wait()) for _ in range(4)]
    assert runtime.cache_stats().exec_misses == misses0
    assert op.starts == 4
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
    # a second op of the same spec is an exec-cache hit, not a compile
    COMM.allreduce_init(x, algo="pip_mcoll")
    assert runtime.cache_stats().exec_misses == misses0


def test_persistent_handle_misuse_errors():
    x = _operand("allreduce", 8, "float32")
    op = COMM.allreduce_init(x, algo="pip_mcoll")  # depth=1
    h = op.start(x)
    with pytest.raises(RuntimeError, match="outstanding"):
        op.start(x)  # start before wait without double buffering
    h.wait()
    with pytest.raises(RuntimeError, match="double wait"):
        h.wait()
    op.start(x).wait()  # slot released: pairing works again
    # depth=2 (double buffering) allows exactly one extra outstanding start
    op2 = COMM.allreduce_init(x, algo="pip_mcoll", depth=2)
    h1, h2 = op2.start(x), op2.start(x)
    with pytest.raises(RuntimeError, match="outstanding"):
        op2.start(x)
    np.testing.assert_array_equal(np.asarray(h1.wait()),
                                  np.asarray(h2.wait()))


def test_persistent_rejects_operand_spec_mismatch():
    x = _operand("allreduce", 8, "float32")
    op = COMM.allreduce_init(x, algo="pip_mcoll")
    with pytest.raises(ValueError, match="compiled for"):
        op.start(_operand("allreduce", 9, "float32"))
    with pytest.raises(ValueError, match="compiled for"):
        op.start(_operand("allreduce", 8, "int32"))


CARRY_ALGOS = sorted({algo for coll, algo in CODEC_PAIRS
                      if coll == "allreduce"
                      and runtime.supports_carry("allreduce", algo)})


@pytest.mark.parametrize("cd", sorted(compress.lossy()))
@pytest.mark.parametrize("algo", CARRY_ALGOS)
def test_persistent_carry_threads_error_feedback(algo, cd):
    """The carry-threaded persistent op (``start(x, carry=err)`` ->
    ``wait() -> (y, new_err)``) is the per-bucket error-feedback hookup of
    the overlapped gradient sync: its result must stay inside the codec's
    stated collective bound, match the runtime's carry program bitwise
    (shared lowering), and be deterministic so the overlap/barrier step
    twins stay bit-identical."""
    if not _feasible("allreduce", algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand("allreduce", 80, "float32")
    e0 = jnp.zeros_like(x)
    op = COMM.persistent("allreduce", x, algo=algo, codec=cd, carry=True)
    assert op.carry
    y1, e1 = op.start(x, carry=e0).wait()
    ref = _run("allreduce", REF["allreduce"], x)
    tol = compress.collective_tolerance(
        cd, "allreduce", M, float(np.abs(np.asarray(x)).max()))
    err = np.abs(np.asarray(y1, np.float32) - ref).max()
    assert err <= tol, f"allreduce/{algo}@{cd} carry: {err} > {tol}"
    fn = runtime.build(COMM.mesh, topo, "allreduce", algo, carry=True,
                       codec=cd)
    ry, re = fn(x, e0)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(ry),
                                  err_msg=f"{algo}@{cd} carry result")
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(re),
                                  err_msg=f"{algo}@{cd} carry state")
    # determinism under a threaded (possibly nonzero) state: the same
    # (payload, err) pair always produces the same (result, state)
    y2a, e2a = op.start(x, carry=e1).wait()
    y2b, e2b = op.start(x, carry=e1).wait()
    np.testing.assert_array_equal(np.asarray(y2a), np.asarray(y2b))
    np.testing.assert_array_equal(np.asarray(e2a), np.asarray(e2b))


# ---------------------------------------------------------------------------
# compressed leg: every (collective x codec) pair vs the xla reference,
# asserting the codec's stated relative-error bound instead of equality
# (CI runs this as its own matrix step via ``-k compressed``)
# ---------------------------------------------------------------------------


def _assert_conforms_compressed(coll: str, algo: str, cd: str, m: int,
                                **kw):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, m, "float32")
    got = _run(coll, algo, x, codec=cd, **kw)
    ref = _run(coll, REF[coll], x)
    tol = compress.collective_tolerance(cd, coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    err = np.abs(got - ref).max()
    assert err <= tol, f"{coll}/{algo}@{cd} m={m} {kw}: {err} > {tol}"


@pytest.mark.parametrize("coll,algo,cd", CODEC_TRIPLES)
def test_conformance_compressed_pairs(coll, algo, cd):
    _assert_conforms_compressed(coll, algo, cd, 80)


@pytest.mark.parametrize("coll,algo", CODEC_PAIRS)
def test_conformance_compressed_none_is_bitwise(coll, algo):
    """codec="none" on a codec-capable algorithm is the lossless algorithm
    exactly — one plan, bitwise equal to the bare call."""
    x = _operand(coll, 5, "float32")
    np.testing.assert_array_equal(_run(coll, algo, x, codec="none"),
                                  _run(coll, algo, x))


@pytest.mark.parametrize(
    "coll,algo", [(c, a) for c, a in CODEC_PAIRS
                  if mcoll.supports_chunks(c, a)])
def test_conformance_compressed_chunked_compose(coll, algo):
    """codec composes with chunks: compressed segments pipeline
    independently and still land inside the codec bound."""
    _assert_conforms_compressed(coll, algo, "int8_block", 80, chunks=3)


@pytest.mark.parametrize("coll", sorted({c for c, _ in CODEC_PAIRS}))
def test_conformance_compressed_auto_budget(coll):
    """algo="auto" under an error budget resolves to a plan (lossless or
    admissible codec) that conforms within the loosest admissible bound."""
    budget = float(compress.meta("int8_block").error_bound)
    x = _operand(coll, 64, "float32")
    got = _run(coll, "auto", x, error_budget=budget)
    ref = _run(coll, REF[coll], x)
    tol = compress.collective_tolerance("int8_block", coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    assert np.abs(got - ref).max() <= tol


def test_compressed_rejects_integer_payloads():
    """Lossy codecs on integer payloads must fail clearly at trace time,
    not silently round token ids (checked before the degenerate-topology
    shortcut, so the error does not depend on the device count)."""
    x = _operand("allreduce", 5, "int32")
    with pytest.raises(ValueError, match="integer payload"):
        _run("allreduce", "pip_mcoll", x, codec="int8_block")
    # ... while auto under a budget resolves integer payloads lossless
    # instead of crashing, and stays exact
    got = _run("allreduce", "auto", x, error_budget=1.0)
    np.testing.assert_array_equal(got, _run("allreduce", REF["allreduce"],
                                            x))


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo,cd", CODEC_TRIPLES)
@given(m=st.sampled_from([1, 7, 64, 300]))
@settings(max_examples=4, deadline=None)
def test_conformance_compressed_shape_sweep(coll, algo, cd, m):
    """Odd / non-block-divisible payloads through every codec pair."""
    _assert_conforms_compressed(coll, algo, cd, m)


# ---------------------------------------------------------------------------
# fused-kernel leg: every fused codec x codec-capable collective x chunk
# plan, A/B against the pure-jnp reference paths (compress.
# jnp_reference_paths flips the routing; the runtime caches key on the
# toggle so the two variants compile separately). Lossy fused codecs agree
# within collective_tolerance (decode+reduce accumulates in a different
# order, which can flip one requantization rounding); lossless plans are
# bitwise invariant under the toggle.
# ---------------------------------------------------------------------------

FUSED_TRIPLES = [(coll, algo, cd) for coll, algo in CODEC_PAIRS
                 for cd in compress.fused_codecs()]


def _assert_fused_matches_jnp(coll: str, algo: str, cd: str, m: int, **kw):
    if not _feasible(coll, algo):
        pytest.skip(f"{algo} infeasible on {N}x{P}")
    x = _operand(coll, m, "float32")
    got_fused = _run(coll, algo, x, codec=cd, **kw)
    with compress.jnp_reference_paths():
        got_jnp = _run(coll, algo, x, codec=cd, **kw)
    tol = compress.collective_tolerance(cd, coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    ab = np.abs(got_fused - got_jnp).max()
    assert ab <= tol, f"{coll}/{algo}@{cd} fused-vs-jnp m={m} {kw}: " \
                      f"{ab} > {tol}"
    # the fused path also conforms to the lossless reference on its own
    ref = _run(coll, REF[coll], x)
    err = np.abs(got_fused - ref).max()
    assert err <= tol, f"{coll}/{algo}@{cd} fused-vs-ref m={m} {kw}: " \
                       f"{err} > {tol}"


@pytest.mark.parametrize("coll,algo,cd", FUSED_TRIPLES)
def test_conformance_fused_matches_jnp_reference(coll, algo, cd):
    _assert_fused_matches_jnp(coll, algo, cd, 80)


@pytest.mark.parametrize("chunks", [2, 3])
@pytest.mark.parametrize(
    "coll,algo,cd", [t for t in FUSED_TRIPLES
                     if mcoll.supports_chunks(t[0], t[1])])
def test_conformance_fused_chunked_plans(coll, algo, cd, chunks):
    """Fusion composes with chunked pipelining: every chunk segment rides
    the fused kernels independently."""
    _assert_fused_matches_jnp(coll, algo, cd, 80, chunks=chunks)


@pytest.mark.parametrize("coll,algo", CODEC_PAIRS)
def test_conformance_fused_toggle_lossless_bitwise(coll, algo):
    """codec="none" never routes through a fused lowering — the toggle
    must be bitwise invisible on lossless plans."""
    x = _operand(coll, 5, "float32")
    a = _run(coll, algo, x, codec="none")
    with compress.jnp_reference_paths():
        b = _run(coll, algo, x, codec="none")
    np.testing.assert_array_equal(a, b)


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo,cd", FUSED_TRIPLES)
@given(m=st.sampled_from([1, 7, 64, 300]))
@settings(max_examples=4, deadline=None)
def test_conformance_fused_shape_sweep(coll, algo, cd, m):
    """Odd / non-block-divisible payloads through every fused pair."""
    _assert_fused_matches_jnp(coll, algo, cd, m)


# ---------------------------------------------------------------------------
# root-encodes-once wire form (broadcast/scatter) + the lossless integer
# packer: compressed one-to-all moves the ROOT's encoded form verbatim, so
# even a lossy codec's output is bitwise decode(encode(x)) on every rank —
# re-encoding at each tree hop would compound the error and break this.
# The reference round trip runs under jit like the collective does (XLA's
# fused scale arithmetic differs from eager by an ulp on some blocks).
# ---------------------------------------------------------------------------


def _jit_roundtrip(cd, flat):
    cdo = compress.codec(cd)
    L = flat.shape[1]
    return np.asarray(jax.jit(lambda v: cdo.decode(cdo.encode(v), L))(flat))


@pytest.mark.parametrize("cd", sorted(compress.lossy()))
def test_broadcast_root_encodes_once_wire_form(cd):
    m = 2 * compress.BLOCK + 7
    x = jax.random.normal(jax.random.PRNGKey(0), (m,), jnp.float32)
    got = np.asarray(COMM.broadcast(x, algo="pip_mcoll", codec=cd))
    want = _jit_roundtrip(cd, x.reshape(1, -1)).reshape(m)
    for d in range(M):
        np.testing.assert_array_equal(
            got[d], want, err_msg=f"broadcast@{cd} rank {d} re-encoded")


@pytest.mark.parametrize("cd", sorted(compress.lossy()))
def test_scatter_root_encodes_once_wire_form(cd):
    m = compress.BLOCK + 3
    x = jax.random.normal(jax.random.PRNGKey(1), (M * m,), jnp.float32)
    got = np.asarray(COMM.scatter(x, algo="pip_mcoll", codec=cd))
    flat = x.reshape(M, -1)  # one wire row per destination rank
    want = _jit_roundtrip(cd, flat)
    np.testing.assert_array_equal(
        got.reshape(M, m), want, err_msg=f"scatter@{cd} re-encoded")


@pytest.mark.parametrize("coll", sorted({c for c, _ in CODEC_PAIRS}
                                        - {"allreduce", "reduce_scatter"}))
def test_zlib_sim_bitwise_on_integer_payloads(coll):
    """The lossless integer packer is bitwise-exact end to end on every
    non-reducing collective (its admissible domain)."""
    x = _operand(coll, 40, "int32")
    got = COMM.invoke(coll, x, algo="pip_mcoll", codec="zlib_sim")
    ref = COMM.invoke(coll, x, algo=REF[coll])
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_zlib_sim_preserves_large_int32_values():
    """Values above 2^24 (unrepresentable in f32) survive: integer-only
    codecs never touch the f32 pre-cast path, and only the per-slice RANGE
    must fit 16 bits."""
    base = 1 << 28
    x = ((jnp.arange(M * 5) % 97) + base).astype(jnp.int32)
    got = np.asarray(COMM.allgather(x, algo="pip_mcoll", codec="zlib_sim"))
    want = np.stack([np.asarray(x)] * M)
    np.testing.assert_array_equal(got, want)


def test_zlib_sim_rejected_on_reducing_and_float():
    x = _operand("allreduce", 5, "int32")
    with pytest.raises(ValueError, match="not additive|not admissible"):
        _run("allreduce", "pip_mcoll", x, codec="zlib_sim")
    xf = _operand("broadcast", 5, "float32")
    with pytest.raises(ValueError, match="float payload|not admissible"):
        _run("broadcast", "pip_mcoll", xf, codec="zlib_sim")


def test_auto_integer_broadcast_can_pick_zlib_sim():
    """Selection layer: for an integer broadcast, zlib_sim is an
    admissible candidate at budget 0 — and an explicit measured entry
    naming it wins resolution."""
    sel = autotune.Selector()
    c = Communicator(mesh, topo, selector=sel)
    sel.table.record(topo, "broadcast", "int32", 4 * 40,
                     autotune.encode_plan("pip_mcoll", 1, "zlib_sim"), 1e-12)
    s = sel.choose("broadcast", topo, 4 * 40, dtype="int32")
    assert (s.algo, s.codec) == ("pip_mcoll", "zlib_sim")
    x = _operand("broadcast", 40, "int32")
    np.testing.assert_array_equal(np.asarray(c.broadcast(x)),
                                  np.asarray(_run("broadcast",
                                                  REF["broadcast"], x)))


# ---------------------------------------------------------------------------
# group leg: comm.split() sub-communicators — every collective x algorithm
# over the mesh split along each axis (and both), asserting bitwise
# equality against the reference algorithm restricted to the group AND a
# pure-numpy group oracle (CI selects this leg with ``-k group``)
# ---------------------------------------------------------------------------

GROUP_AXES = [("node",), ("local",), ("node", "local")]
GROUP_IDS = ["node", "local", "node-local"]


def _group_members(axes):
    """Flat mesh ranks of every group, each in group-rank order (mesh is
    (N, P) row-major: flat rank d = n * P + p)."""
    if axes == ("node",):
        return [[n * P + p for n in range(N)] for p in range(P)]
    if axes == ("local",):
        return [[n * P + p for p in range(P)] for n in range(N)]
    return [list(range(M))]


def _group_operand(coll: str, G: int, m: int, dtype: str):
    """Global operand per the group I/O convention (D = mesh devices,
    G = group world; see runtime.build)."""
    dt = jnp.dtype(dtype)
    if coll == "allgather":
        return (jnp.arange(M * m) % 97).astype(dt)
    if coll == "scatter":
        return (jnp.arange(G * m) % 97).astype(dt)
    if coll == "broadcast":
        return (jnp.arange(m) % 97 + 1).astype(dt)
    if coll == "allreduce":
        return (jnp.arange(M * m) % 5).astype(dt).reshape(M, m)
    if coll == "reduce_scatter":
        return (jnp.arange(M * G * m) % 5).astype(dt).reshape(M, G * m)
    if coll == "alltoall":
        return (jnp.arange(M * G * m) % 97).astype(dt).reshape(M, G, m)
    raise ValueError(coll)


def _group_oracle(coll: str, x, members, m: int):
    """Pure-numpy group collective: every group reduces/gathers over its
    own members only."""
    a = np.asarray(x.astype(jnp.float32))
    where = {d: (mem, r) for mem in members for r, d in enumerate(mem)}
    G = len(members[0])
    if coll == "allgather":
        return np.stack([np.concatenate(
            [a[j * m:(j + 1) * m] for j in where[d][0]]) for d in range(M)])
    if coll == "broadcast":
        return np.stack([a] * M)
    if coll == "scatter":
        return np.concatenate(
            [a[where[d][1] * m:(where[d][1] + 1) * m] for d in range(M)])
    if coll == "allreduce":
        return np.stack([a[where[d][0]].sum(0) for d in range(M)])
    if coll == "reduce_scatter":
        s = a.shape[1] // G
        return np.concatenate(
            [a[where[d][0]].sum(0)[where[d][1] * s:(where[d][1] + 1) * s]
             for d in range(M)])
    if coll == "alltoall":
        out = np.empty_like(a)
        for d in range(M):
            mem, r = where[d]
            for j in range(G):
                out[d, j] = a[mem[j], r]
        return out
    raise ValueError(coll)


@pytest.mark.parametrize("axes", GROUP_AXES, ids=GROUP_IDS)
@pytest.mark.parametrize("coll", sorted(runtime.collectives()))
def test_group_conformance_every_algorithm(coll, axes):
    g = COMM.split(axes=axes if len(axes) > 1 else axes[0])
    members = _group_members(axes)
    m = 3
    x = _group_operand(coll, g.topo.world, m, "float32")
    want = _group_oracle(coll, x, members, m)
    ref = np.asarray(g.invoke(coll, x, algo=REF[coll]).astype(jnp.float32))
    np.testing.assert_array_equal(
        ref, want, err_msg=f"group {axes} {coll}/{REF[coll]} vs oracle")
    for algo in autotune.candidates(coll, g.topo):
        got = np.asarray(g.invoke(coll, x, algo=algo).astype(jnp.float32))
        np.testing.assert_array_equal(
            got, ref, err_msg=f"group {axes} {coll}/{algo}")


@pytest.mark.parametrize("axes", GROUP_AXES, ids=GROUP_IDS)
def test_group_conformance_root_sweep(axes):
    g = COMM.split(axes=axes if len(axes) > 1 else axes[0])
    G = g.topo.world
    members = _group_members(axes)
    for coll in ("broadcast", "scatter"):
        x = _group_operand(coll, G, 4, "float32")
        want = _group_oracle(coll, x, members, 4)
        for root in sorted({0, G - 1}):
            got = np.asarray(
                g.invoke(coll, x, algo="pip_mcoll", root=root)
                .astype(jnp.float32))
            np.testing.assert_array_equal(
                got, want, err_msg=f"group {axes} {coll} root={root}")


@pytest.mark.parametrize("axes", GROUP_AXES, ids=GROUP_IDS)
def test_group_persistent_matches_blocking(axes):
    g = COMM.split(axes=axes if len(axes) > 1 else axes[0])
    x = _group_operand("allreduce", g.topo.world, 6, "float32")
    op = g.allreduce_init(x, algo="pip_mcoll")
    np.testing.assert_array_equal(
        np.asarray(op.start(x).wait()),
        np.asarray(g.allreduce(x, algo="pip_mcoll")))


@pytest.mark.parametrize("axes", GROUP_AXES, ids=GROUP_IDS)
def test_group_compressed_broadcast_in_bounds(axes):
    g = COMM.split(axes=axes if len(axes) > 1 else axes[0])
    m = 2 * compress.BLOCK + 5
    x = jax.random.normal(jax.random.PRNGKey(2), (m,), jnp.float32)
    got = np.asarray(g.broadcast(x, algo="pip_mcoll", codec="int8_block"))
    want = np.stack([np.asarray(x)] * M)
    tol = compress.collective_tolerance(
        "int8_block", "broadcast", g.topo.world, float(jnp.abs(x).max()))
    assert np.abs(got - want).max() <= tol + 1e-6


def test_group_split_of_split_matches_direct():
    """comm.split(...).split(...) lands on the same group semantics as the
    direct split (and the same memoized child when specs agree)."""
    direct = COMM.split(axes="local")
    nested = COMM.split(axes=("node", "local")).split(axes="local")
    x = _group_operand("allreduce", direct.topo.world, 4, "float32")
    np.testing.assert_array_equal(
        np.asarray(direct.allreduce(x, algo="pip_mcoll")),
        np.asarray(nested.allreduce(x, algo="pip_mcoll")))


def test_group_split_lattice_calibration_lands_measured_rows():
    """comm.calibrate(include_splits=True) walks the split lattice: every
    mesh-aligned group shape gets measured /g:-keyed tuning rows before
    first use, in the one shared selector table."""
    from repro.core import autotune as _autotune
    from repro.core.comm import Communicator as _Comm

    local = _Comm(mesh, topo, selector=_autotune.Selector(
        table=_autotune.TuningTable()))
    kids = local.split_lattice()
    active = tuple(topo.active_axes)
    want_groups = {"x".join(c) for c in
                   ([(a,) for a in active]
                    + ([tuple(active)] if len(active) > 1 else []))}
    assert {k.topo.group for k in kids} == want_groups
    rows = local.calibrate(include_splits=True, names=("allreduce",),
                           sizes=(256,), iters=1)
    assert {r.group for r in rows} == want_groups | {""}
    # every lattice child resolves auto from measurement, not the prior
    for k in kids:
        assert local.selector.table.lookup(
            k.topo, "allreduce", "float32", 256) is not None
        assert _autotune.topo_key(k.topo).endswith(f"/g:{k.topo.group}")


@pytest.mark.parametrize("coll", ("allreduce", "reduce_scatter"))
def test_conformance_compressed_multidim_payload(coll):
    """Compressed reductions accept trailing payload dims like their
    lossless forms ('(M*s, ...)' input), flattening row-major internally."""
    if coll == "allreduce":
        x = (jnp.arange(M * 10 * 3) % 5).astype(jnp.float32).reshape(
            M, 10, 3)
    else:
        x = (jnp.arange(M * M * 4 * 3) % 5).astype(jnp.float32).reshape(
            M, M * 4, 3)
    got = _run(coll, "pip_mcoll", x, codec="int8_block")
    ref = _run(coll, REF[coll], x)
    assert got.shape == ref.shape
    tol = compress.collective_tolerance("int8_block", coll, M,
                                        float(jnp.abs(x).max())) + 1e-6
    assert np.abs(got - ref).max() <= tol


# ---------------------------------------------------------------------------
# slow legs: dtype x odd-shape sweep, chunk-count sweep, auto-plan sweep
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo", PAIRS)
@given(m=st.sampled_from([1, 3, 6, 7]), dtype=st.sampled_from(DTYPES))
@settings(max_examples=8, deadline=None)
def test_conformance_dtype_shape_sweep(coll, algo, m, dtype):
    _assert_conforms(coll, algo, m, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("coll,algo", CHUNKED_PAIRS)
@given(m=st.sampled_from([1, 4, 7]), chunks=st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_conformance_chunk_sweep(coll, algo, m, chunks):
    # chunk counts beyond the payload clamp internally; remainder segments
    # must round-trip exactly (zero padding never leaks into results)
    _assert_conforms(coll, algo, m, "float32", chunks=chunks)


@pytest.mark.slow
@pytest.mark.parametrize("coll", sorted(runtime.collectives()))
@given(m=st.sampled_from([1, 5, 64]), dtype=st.sampled_from(DTYPES))
@settings(max_examples=6, deadline=None)
def test_conformance_auto_plan(coll, m, dtype):
    """algo="auto" resolves an (algo, chunks) plan that conforms too."""
    x = _operand(coll, m, dtype)
    got = _run(coll, "auto", x)
    ref = _run(coll, REF[coll], x)
    np.testing.assert_array_equal(got, ref,
                                  err_msg=f"{coll}/auto m={m} {dtype}")


# ---------------------------------------------------------------------------
# pure-logic properties: chunk planning math (no devices involved)
# ---------------------------------------------------------------------------


@given(rounds=st.integers(2, 512), nbytes=st.integers(64, 1 << 26))
@settings(max_examples=60, deadline=None)
def test_optimal_pipeline_chunks_is_local_minimum(rounds, nbytes):
    """The analytic c* beats its integer neighbors under the stage model
    (C + B/c·beta)(rounds + c − 1)."""
    alpha, beta = 1.0e-6, 1 / 2.5e10
    c = costmodel.optimal_pipeline_chunks(alpha, nbytes, beta, rounds)
    t = costmodel.pipeline_time(alpha, nbytes, beta, rounds, c)
    assert 1 <= c <= costmodel.MAX_CHUNKS
    if c > 1:
        assert t <= costmodel.pipeline_time(alpha, nbytes, beta, rounds,
                                            c - 1) * (1 + 1e-12)
    if c < costmodel.MAX_CHUNKS:
        assert t <= costmodel.pipeline_time(alpha, nbytes, beta, rounds,
                                            c + 1) * (1 + 1e-12)


@given(nbytes=st.sampled_from([256, 4096, 1 << 16, 1 << 20, 1 << 24]))
@settings(max_examples=10, deadline=None)
def test_pipeline_crossover_vs_unchunked(nbytes):
    """The cost model must show the pipelining crossover: chunking never
    helps the latency regime, and wins the bandwidth regime."""
    t16 = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    net = costmodel.net_for(t16)
    c = costmodel.optimal_chunks("allreduce", "pip_pipeline", t16, nbytes,
                                 net)
    t1 = costmodel.allreduce_cost("pip_pipeline", t16, nbytes, net,
                                  chunks=1).time
    tc = costmodel.allreduce_cost("pip_pipeline", t16, nbytes, net,
                                  chunks=c).time
    assert tc <= t1 * (1 + 1e-12)
    if nbytes >= 1 << 20:
        assert c > 1 and tc < t1, (nbytes, c)
    if nbytes <= 256:
        assert c == 1


def test_scatter_rejects_non_divisible_payload():
    """Regression: a payload that cannot shard evenly used to silently
    truncate (dim0 // world); it must be a clear error instead."""
    if M == 1:
        pytest.skip("every payload divides on 1 device")
    x = jnp.arange(float(M * 3 + 1))
    with pytest.raises(ValueError, match="divisible by world"):
        COMM.scatter(x, algo="pip_mcoll")


def test_plan_encode_decode_round_trip():
    assert autotune.encode_plan("pip_pipeline", 1) == "pip_pipeline"
    assert autotune.encode_plan("pip_pipeline", 8) == "pip_pipeline#c8"
    assert autotune.encode_plan("pip_pipeline", 8, "int8_block") == \
        "pip_pipeline#c8@int8_block"
    assert autotune.encode_plan("pip_mcoll", 1, "topk") == "pip_mcoll@topk"
    assert autotune.decode_plan("pip_pipeline#c8") == \
        ("pip_pipeline", 8, "none")
    assert autotune.decode_plan("pip_pipeline#c8@int8_block") == \
        ("pip_pipeline", 8, "int8_block")
    assert autotune.decode_plan("pip_mcoll@fp8_sim") == \
        ("pip_mcoll", 1, "fp8_sim")
    assert autotune.decode_plan("ring") == ("ring", 1, "none")


def test_plans_cover_registry_with_chunk_and_codec_variants():
    t = Topology(4, 4, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    for coll in runtime.collectives():
        ps = autotune.plans(coll, t, 1 << 20)
        algos = {a for a, _, _ in ps}
        assert algos == set(autotune.candidates(coll, t))
        for a, c, cd in ps:
            assert c >= 1
            if c > 1:
                assert mcoll.supports_chunks(coll, a)
            if cd != "none":
                assert mcoll.supports_codec(coll, a)
        # every chunk-capable algorithm gets at least one chunked variant
        # at a bandwidth-regime size
        for a in algos:
            if mcoll.supports_chunks(coll, a):
                assert any(c > 1 for aa, c, _ in ps if aa == a), (coll, a)
        # every codec-capable algorithm gets every lossy codec variant
        for a in algos:
            if mcoll.supports_codec(coll, a):
                planned = {cd for aa, _, cd in ps if aa == a}
                assert set(compress.lossy()) <= planned, (coll, a)

"""Multi-process SPMD backend: single-process unit coverage of
repro.distributed (backend descriptors, launcher plumbing, process-aware
link derivation, cross-rank table merging) plus the spawned 2-process
conformance legs (subprocess-contained device counts)."""
import sys

import numpy as np
import pytest

from subproc import run_check

from repro.core import artifact, topology
from repro.core.autotune import TuningTable
from repro.core.topology import Topology, derive_link
from repro.distributed import backend as dist
from repro.distributed import launch


# -- backend descriptor (this pytest process is single-process) --------------


def test_single_process_backend():
    be = dist.current_backend()
    assert be.name == "single" and be.process_count == 1 \
        and be.process_index == 0 and not be.multiprocess
    assert dist.auto_initialize() == be  # no REPRO_DIST_* env -> no-op
    assert not dist.is_multiprocess()
    assert dist.process_rank() == 0 and dist.process_count() == 1
    dist.barrier("noop")  # must not require an initialized service
    assert dist.merge_tuning_table(TuningTable()) == 0


def test_to_host_and_stamp():
    x = np.arange(6.0).reshape(2, 3)
    np.testing.assert_array_equal(dist.to_host(x), x)
    data = dist.stamp_artifact({"topology": "1x1/host_cpu/host_cpu"})
    assert data["backend"] == "single" and data["process_count"] == 1


def test_stamped_fields_satisfy_artifact_schema():
    data = dist.stamp_artifact({})
    assert artifact.validate(data, sections=("backend", "process_count"))


# -- launcher plumbing -------------------------------------------------------


def test_worker_env_contract():
    env = launch._worker_env(
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=8 --foo"},
        rank=1, processes=2, devices_per_process=4,
        coord="127.0.0.1:5555", scratch="/tmp/s")
    # the parent's forced device count is replaced, other flags survive
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "=8" not in env["XLA_FLAGS"] and "--foo" in env["XLA_FLAGS"]
    assert env[dist.ENV_PROCS] == "2" and env[dist.ENV_RANK] == "1"
    assert env[dist.ENV_COORD] == "127.0.0.1:5555"
    assert env[dist.ENV_SCRATCH] == "/tmp/s"
    assert str(launch.SRC) in env["PYTHONPATH"]


def test_fn_ref_forms():
    ref = launch._fn_ref("repro.core.runtime:collectives")
    assert ref == {"kind": "module", "module": "repro.core.runtime",
                   "name": "collectives"}
    assert callable(launch._resolve_fn(ref))
    with pytest.raises(ValueError, match="module:function"):
        launch._fn_ref("not-a-spec")
    with pytest.raises(ValueError, match="module-level"):
        launch._fn_ref(lambda: None)


def test_spawn_failure_carries_rank_tails():
    with pytest.raises(launch.LaunchError, match="rank 0"):
        launch.spawn([sys.executable, "-c",
                      "import sys; print('boom'); sys.exit(3)"],
                     processes=1, devices_per_process=1, timeout=60)


# -- process-aware link derivation (fake devices, no spawn needed) -----------


class _Dev:
    def __init__(self, platform, process_index, slice_index=None):
        self.platform = platform
        self.process_index = process_index
        if slice_index is not None:
            self.slice_index = slice_index


class _FakeMesh:
    axis_names = ("node", "local")

    def __init__(self, rows):
        self.devices = np.array(rows, dtype=object)

    @property
    def shape(self):
        return {"node": self.devices.shape[0],
                "local": self.devices.shape[1]}


def _mesh(platform, procs, per_proc):
    return _FakeMesh([[_Dev(platform, p) for _ in range(per_proc)]
                      for p in range(procs)])


def test_derive_link_splits_on_process_boundary():
    mesh = _mesh("cpu", 2, 4)
    assert derive_link(mesh, "node", "inter") == "host_ipc"
    assert derive_link(mesh, "local", "intra") == "host_cpu"
    topo = Topology.from_mesh(mesh)
    assert topo.link_names == ("host_ipc", "host_cpu")


def test_derive_link_single_process_cpu_stays_host_cpu():
    mesh = _mesh("cpu", 1, 4)
    assert derive_link(mesh, "node", "inter") == "host_cpu"
    assert derive_link(mesh, "local", "intra") == "host_cpu"


def test_derive_link_unknown_platform_warns_once():
    topology._FALLBACK_WARNED.discard("gpu")
    mesh = _mesh("gpu", 2, 2)
    with pytest.warns(RuntimeWarning, match="folklore"):
        assert derive_link(mesh, "node", "inter") == "host_ipc"
    # second call: already warned for this platform
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert derive_link(mesh, "local", "intra") == "host_cpu"


def test_derive_link_tpu_unchanged():
    mesh = _mesh("tpu", 2, 2)
    assert derive_link(mesh, "node", "inter") == "tpu_v5e_dcn"
    assert derive_link(mesh, "local", "intra") == "tpu_v5e_ici"


# -- cross-rank table merge semantics ----------------------------------------


def test_merge_reduce_max_keeps_slowest_rank():
    topo = Topology(2, 4, node_link="host_ipc", local_link="host_cpu")
    a, b = TuningTable(), TuningTable()
    a.record(topo, "allreduce", "float32", 4096, "pip_mcoll", 1e-4)
    b.record(topo, "allreduce", "float32", 4096, "pip_mcoll", 3e-4)
    b.record(topo, "allreduce", "float32", 4096, "ring", 2e-4)
    a.merge(b, reduce=max)
    entry = a.lookup(topo, "allreduce", "float32", 4096)
    assert entry["pip_mcoll"] == pytest.approx(3e-4)  # slowest rank wins
    assert entry["ring"] == pytest.approx(2e-4)       # new keys fold in
    # default merge keeps other-wins semantics
    c = TuningTable()
    c.record(topo, "allreduce", "float32", 4096, "pip_mcoll", 9e-4)
    a.merge(c)
    assert a.lookup(topo, "allreduce", "float32",
                    4096)["pip_mcoll"] == pytest.approx(9e-4)


# -- spawned multi-controller legs ------------------------------------------


@pytest.mark.parametrize("procs,dev", [
    pytest.param(2, 2, id="2x2"),
    pytest.param(2, 4, id="2x4", marks=pytest.mark.slow),
])
def test_multiprocess_conformance(procs, dev):
    out = run_check("multiproc_conformance_check.py", procs * dev,
                    procs, dev, timeout=1800)
    assert "MULTIPROC_CONFORMANCE_OK" in out
    assert f"topo={procs}x{dev}/host_ipc/host_cpu" in out

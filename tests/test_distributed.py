"""Multi-device integration: manual mcoll train step vs pjit reference,
MoE expert parallelism vs local oracle, and a small-mesh sharded train step
(subprocess-contained device counts)."""
import pytest

from subproc import run_check


@pytest.mark.parametrize("n,p", [(2, 2), (4, 2)])
def test_manual_mcoll_train_step(n, p):
    out = run_check("manual_step_check.py", n * p, n, p)
    assert "OK" in out


@pytest.mark.parametrize("dp,tp", [(1, 2), (2, 2), (1, 4), (2, 4)])
def test_moe_expert_parallel(dp, tp):
    out = run_check("moe_ep_check.py", dp * tp, dp, tp)
    assert "OK" in out


def test_sharded_train_step_small_mesh():
    out = run_check("sharded_train_check.py", 8)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_pipeline_small_mesh():
    """build_cell -> lower -> compile -> roofline on an 8-device mesh for
    one arch per family and every shape kind."""
    out = run_check("dryrun_smoke_check.py", 8, timeout=1200)
    assert "dryrun_smoke_check OK" in out


@pytest.mark.parametrize("n,p", [(2, 2), (4, 2)])
def test_compressed_allreduce_int8_wire(n, p):
    out = run_check("compressed_allreduce_check.py", n * p, n, p)
    assert "OK" in out

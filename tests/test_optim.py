"""Optimizer + compression units and properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.optim import adamw, compress


def test_adamw_matches_reference_math():
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, grad_clip=1e9,
                            warmup_steps=0, total_steps=10,
                            schedule="constant")
    p = {"w": jnp.array([1.0, -2.0], jnp.float32)}
    g = {"w": jnp.array([0.5, 0.5], jnp.float32)}
    st_ = adamw.init(p, cfg)
    new_p, new_st, _ = adamw.update(p, g, st_, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"][0]), want, rtol=1e-5)


def test_weight_decay_mask_skips_norms():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9,
                            warmup_steps=0, schedule="constant")
    p = {"dense": {"w": jnp.ones((2,), jnp.float32)},
         "ln1": {"scale": jnp.ones((2,), jnp.float32)}}
    g = jax.tree.map(jnp.zeros_like, p)
    st_ = adamw.init(p, cfg)
    new_p, _, _ = adamw.update(p, g, st_, cfg)
    # zero grads: decayed params shrink, no-decay params don't
    assert float(new_p["dense"]["w"][0]) < 1.0
    assert float(new_p["ln1"]["scale"][0]) == 1.0


@given(norm=st.floats(0.1, 100.0), clip=st.floats(0.5, 10.0))
@settings(max_examples=50, deadline=None)
def test_clip_property(norm, clip):
    g = {"w": jnp.array([norm, 0.0], jnp.float32)}
    clipped, gn = adamw.clip_by_global_norm(g, clip)
    out_norm = float(adamw.global_norm(clipped))
    assert out_norm <= clip * 1.001
    if norm <= clip:
        np.testing.assert_allclose(out_norm, norm, rtol=1e-4)


def test_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1, schedule="cosine")
    lrs = [float(adamw.schedule_lr(cfg, jnp.int32(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert 0.1 < lrs[3] < 1.0                 # decaying
    assert abs(lrs[4] - 0.1) < 1e-3           # floor


# -- compression -------------------------------------------------------------


@given(scale=st.floats(1e-4, 1e3), n=st.integers(1, 2000),
       seed=st.integers(0, 100))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_error_bound(scale, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
    q, s = compress.quantize(x)
    back = compress.dequantize(q, s, x.shape)
    # per-block max error <= scale_block (= blockmax/127) / 2
    err = np.abs(np.array(back) - np.array(x))
    blockmax = np.abs(np.array(x)).max()
    assert err.max() <= blockmax / 127.0 * 0.5 + 1e-7


def test_error_feedback_reduces_bias():
    """With error feedback, the running sum of dequantized grads tracks the
    running sum of true grads much better than without."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (512,)) * 1e-3
    grads = {"w": g_true}
    err = compress.init_error_state(grads)
    acc_fb = np.zeros(512)
    acc_nofb = np.zeros(512)
    for i in range(20):
        comp, err = compress.compress_tree(grads, err)
        acc_fb += np.array(compress.decompress_tree(comp, grads)["w"])
        comp2, _ = compress.compress_tree(
            grads, compress.init_error_state(grads))
        acc_nofb += np.array(compress.decompress_tree(comp2, grads)["w"])
    true = np.array(g_true) * 20
    assert np.abs(acc_fb - true).max() <= np.abs(acc_nofb - true).max() + 1e-9


def test_wire_bytes_ratio():
    grads = {"w": jnp.ones((4096,), jnp.float32)}
    err = compress.init_error_state(grads)
    comp, _ = compress.compress_tree(grads, err)
    bf16_bytes = 4096 * 2
    assert compress.wire_bytes(comp) < bf16_bytes * 0.6  # ~3.7x vs bf16

"""Compat shim + collective runtime: version resolution, kwarg spelling,
build/exec cache behavior, and the no-direct-shard_map regression grep.

Cache tests run in-process on 1-device meshes (a (1, 1) node x local mesh
is a valid degenerate topology), keeping device-count containment intact.
All cache tests drive the runtime through the Communicator (the supported
surface, via ``_coll``); the ``runtime.collective`` deprecation shim has
its own tests in test_comm.py.
"""
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import comm as comm_mod
from repro.core import compat, runtime
from repro.core.topology import Topology

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


def _coll(mesh, topo, name, algo, x, **kw):
    return comm_mod.communicator(mesh, topo).invoke(name, x, algo=algo, **kw)


# ---------------------------------------------------------------------------
# compat: implementation resolution + kwarg translation
# ---------------------------------------------------------------------------


def test_compat_picks_installed_impl():
    """The shim must resolve to the implementation this JAX actually has,
    in preference order jax.shard_map > jax.sharding > experimental."""
    if getattr(jax, "shard_map", None) is not None:
        assert compat.SHARD_MAP_SOURCE == "jax"
    elif getattr(jax.sharding, "shard_map", None) is not None:
        assert compat.SHARD_MAP_SOURCE == "jax.sharding"
    else:
        from jax.experimental import shard_map as esm
        assert esm.shard_map is not None
        assert compat.SHARD_MAP_SOURCE == "jax.experimental.shard_map"


def test_compat_kwarg_spelling_matches_impl():
    import inspect
    params = inspect.signature(compat._shard_map_impl).parameters
    if "check_vma" in params:
        assert compat.CHECK_KW == "check_vma"
    elif "check_rep" in params:
        assert compat.CHECK_KW == "check_rep"
    else:
        assert compat.CHECK_KW is None


def test_compat_shard_map_executes():
    mesh = jax.make_mesh((1,), ("d",))
    fn = compat.shard_map(lambda x: x * 2, mesh, in_specs=(P("d"),),
                          out_specs=P("d"), check_vma=False)
    np.testing.assert_array_equal(np.asarray(fn(jnp.arange(4.0))),
                                  np.arange(4.0) * 2)
    # the check_rep alias spelling must work too
    fn2 = compat.shard_map(lambda x: x + 1, mesh, in_specs=(P("d"),),
                           out_specs=P("d"), check_rep=False)
    np.testing.assert_array_equal(np.asarray(fn2(jnp.zeros(2))), np.ones(2))
    with pytest.raises(TypeError):
        compat.shard_map(lambda x: x, mesh, in_specs=(P("d"),),
                         out_specs=P("d"), check_vma=False, check_rep=False)


# ---------------------------------------------------------------------------
# runtime: build cache + compiled-callable (exec) cache
# ---------------------------------------------------------------------------


def _mesh_topo(node="node", local="local"):
    mesh = jax.make_mesh((1, 1), (node, local))
    return mesh, Topology(1, 1, node_axis=node, local_axis=local)


def test_build_cache_identity_and_invalidation():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    f1 = runtime.build(mesh, topo, "allgather", "xla")
    f2 = runtime.build(mesh, topo, "allgather", "xla")
    assert f1 is f2, "identical key must return the identical callable"
    f3 = runtime.build(mesh, topo, "allgather", "pip_mcoll")
    assert f3 is not f1, "algo change must build fresh"
    f4 = runtime.build(mesh, topo, "allgather", "xla", stacked=False)
    assert f4 is not f1, "kwarg change must build fresh"
    s = runtime.cache_stats()
    assert s.build_hits == 1 and s.build_misses == 3


def test_exec_cache_hit_on_identical_key():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    x = jnp.arange(4.0)
    out1 = _coll(mesh, topo, "allgather", "xla", x)
    out2 = _coll(mesh, topo, "allgather", "xla", x)
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1)[0], np.asarray(x))


def test_exec_cache_fresh_on_shape_dtype_algo_mesh_change():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    _coll(mesh, topo, "allgather", "xla", jnp.arange(4.0))
    _coll(mesh, topo, "allgather", "xla", jnp.arange(8.0))
    assert runtime.cache_stats().exec_misses == 2, "shape change re-compiles"
    _coll(mesh, topo, "allgather", "xla",
                       jnp.arange(4, dtype=jnp.int32))
    assert runtime.cache_stats().exec_misses == 3, "dtype change re-compiles"
    _coll(mesh, topo, "allgather", "pip_mcoll", jnp.arange(4.0))
    assert runtime.cache_stats().exec_misses == 4, "algo change re-compiles"
    mesh2, topo2 = _mesh_topo("n2", "l2")
    _coll(mesh2, topo2, "allgather", "xla", jnp.arange(4.0))
    assert runtime.cache_stats().exec_misses == 5, "mesh change re-compiles"
    assert runtime.cache_stats().exec_hits == 0


def test_collective_correct_through_cache():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.arange(6.0).reshape(1, 6)
    for _ in range(2):  # second pass: every call a cache hit, same results
        out = _coll(mesh, topo, "allreduce", "pip_mcoll", z)
        np.testing.assert_allclose(np.asarray(out), np.asarray(z))
    assert runtime.cache_stats().exec_hits == 1


def test_unknown_collective_rejected():
    mesh, topo = _mesh_topo()
    with pytest.raises(ValueError):
        runtime.build(mesh, topo, "gossip", "xla")


def test_build_rejects_auto():
    """auto needs an operand (size/dtype drive selection) — build has none."""
    mesh, topo = _mesh_topo()
    with pytest.raises(ValueError):
        runtime.build(mesh, topo, "allgather", "auto")


# ---------------------------------------------------------------------------
# chunked plans in the exec cache
# ---------------------------------------------------------------------------


def test_exec_cache_chunked_plans_do_not_collide():
    """The same algorithm at different chunk counts compiles different
    programs — the exec-cache key must separate them."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    _coll(mesh, topo, "allreduce", "pip_pipeline", z, chunks=1)
    _coll(mesh, topo, "allreduce", "pip_pipeline", z, chunks=2)
    assert runtime.cache_stats().exec_misses == 2, "chunk change re-compiles"
    _coll(mesh, topo, "allreduce", "pip_pipeline", z, chunks=2)
    s = runtime.cache_stats()
    assert s.exec_hits == 1 and s.exec_misses == 2, s


def test_exec_cache_default_chunks_normalized():
    """Omitting ``chunks`` on a chunk-capable algorithm is the same plan as
    ``chunks=1`` — one cache entry, not two."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    _coll(mesh, topo, "allreduce", "pip_pipeline", z)
    _coll(mesh, topo, "allreduce", "pip_pipeline", z, chunks=1)
    s = runtime.cache_stats()
    assert s.exec_hits == 1 and s.exec_misses == 1, s


def test_exec_cache_kwargs_normalization_single_entry():
    """The PlanSpec normalization point: ``chunks=None``, ``chunks=1``,
    ``codec=None``, ``codec="none"`` and the bare call are ONE plan — a
    single exec-cache entry through every call-path spelling (the kwargs
    drift that used to risk distinct entries per spelling)."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    comm = comm_mod.communicator(mesh, topo)
    comm.allreduce(z, algo="pip_pipeline")
    comm.allreduce(z, algo="pip_pipeline", chunks=1)
    comm.allreduce(z, algo="pip_pipeline", chunks=None)
    comm.allreduce(z, algo="pip_pipeline", codec=None)
    comm.allreduce(z, algo="pip_pipeline", codec="none")
    comm.allreduce(z, algo="pip_pipeline", chunks=None, codec=None)
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 5, s
    # the persistent path of the same plan shares the build cache but pins
    # the operand sharding, so it compiles exactly one more executable —
    # and every later init of the spec is a hit
    op = comm.allreduce_init(z, algo="pip_pipeline", chunks=None, codec=None)
    op2 = comm.allreduce_init(z, algo="pip_pipeline", chunks=1,
                              codec="none")
    s = runtime.cache_stats()
    assert s.exec_misses == 2 and s.exec_hits == 6, s


def test_plan_spec_validates_at_construction():
    """PlanSpec rejects bad knobs before any trace happens."""
    with pytest.raises(ValueError, match="unknown collective"):
        comm_mod.PlanSpec("gossip")
    with pytest.raises(ValueError, match="chunks"):
        comm_mod.PlanSpec("allreduce", chunks=0)
    with pytest.raises(ValueError, match="chunk_bytes"):
        comm_mod.PlanSpec("allreduce", chunk_bytes=0)
    with pytest.raises(ValueError, match="error_budget"):
        comm_mod.PlanSpec("allreduce", error_budget=-0.5)
    with pytest.raises(TypeError, match="schedule"):
        comm_mod.PlanSpec("allreduce", error_budget=lambda s: 0.0)


def test_auto_and_explicit_chunked_callers_share_entries():
    """auto resolves to an (algo, chunks) plan whose exec-cache entry is
    the one an explicit caller of the same plan uses."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 1 << 20), jnp.float32)  # bandwidth regime
    algo, kw = runtime.resolve_algo(topo, "allreduce", "auto", z)
    _coll(mesh, topo, "allreduce", algo, z, **kw)  # explicit
    _coll(mesh, topo, "allreduce", "auto", z)      # auto: hit
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1, s


def test_chunk_bytes_converts_to_chunks_plan():
    """chunk_bytes is sugar for chunks=ceil(payload/chunk_bytes) and shares
    the cache entry with the equivalent explicit chunks."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 1024), jnp.float32)  # payload 4096 B
    algo, kw = runtime.resolve_algo(topo, "allreduce", "pip_pipeline", z,
                                    {"chunk_bytes": 1024})
    assert algo == "pip_pipeline" and kw == {"chunks": 4, "codec": "none"}, kw
    _coll(mesh, topo, "allreduce", "pip_pipeline", z,
                       chunk_bytes=1024)
    _coll(mesh, topo, "allreduce", "pip_pipeline", z, chunks=4)
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1, s


def test_chunks_on_non_capable_algo_rejected_clearly():
    """chunks/chunk_bytes with an algorithm that has no pipelined form must
    be a clear resolution-time error, not a TypeError mid-trace."""
    mesh, topo = _mesh_topo()
    z = jnp.ones((1, 64), jnp.float32)
    with pytest.raises(ValueError, match="does not support chunking"):
        _coll(mesh, topo, "allreduce", "xla", z, chunks=2)
    with pytest.raises(ValueError, match="does not support chunking"):
        _coll(mesh, topo, "allreduce", "xla", z, chunk_bytes=64)


def test_calibrate_records_chunked_plans(tmp_path):
    """Calibration measures chunk-count variants for the pipelined
    algorithms and records them under plan keys the selector decodes."""
    from repro.core import autotune as at
    mesh, topo = _mesh_topo()
    sel = at.Selector()
    rows = runtime.calibrate(mesh, topo, names=("allreduce",),
                             sizes=(1 << 20,), iters=1, selector=sel)
    assert any(r.algo == "pip_pipeline" and r.chunks > 1 for r in rows), \
        "no chunked plan measured at a bandwidth-regime size"
    measured = sel.table.lookup(topo, "allreduce", "float32", 1 << 20)
    assert any(at.decode_plan(k)[1] > 1 for k in measured), measured
    s = sel.choose("allreduce", topo, 1 << 20)
    assert s.source == "measured" and s.chunks >= 1


# ---------------------------------------------------------------------------
# codec plans in the exec cache
# ---------------------------------------------------------------------------


def test_exec_cache_codec_plans_do_not_collide():
    """The same algorithm with different codecs compiles different
    programs — the exec-cache key must separate them."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    _coll(mesh, topo, "allreduce", "pip_mcoll", z)
    _coll(mesh, topo, "allreduce", "pip_mcoll", z,
                       codec="int8_block")
    assert runtime.cache_stats().exec_misses == 2, "codec change re-compiles"
    _coll(mesh, topo, "allreduce", "pip_mcoll", z,
                       codec="int8_block")
    s = runtime.cache_stats()
    assert s.exec_hits == 1 and s.exec_misses == 2, s


def test_exec_cache_default_codec_normalized():
    """Omitting ``codec`` on a codec-capable algorithm is the same plan as
    ``codec="none"`` — one cache entry, not two; and a zero-budget auto
    resolution shares it too."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    _coll(mesh, topo, "allreduce", "pip_mcoll", z)
    _coll(mesh, topo, "allreduce", "pip_mcoll", z, codec="none")
    s = runtime.cache_stats()
    assert s.exec_hits == 1 and s.exec_misses == 1, s


def test_codec_on_non_capable_algo_rejected_clearly():
    mesh, topo = _mesh_topo()
    z = jnp.ones((1, 64), jnp.float32)
    with pytest.raises(ValueError, match="does not support compression"):
        _coll(mesh, topo, "allreduce", "xla", z,
                           codec="int8_block")
    with pytest.raises(ValueError, match="unknown codec"):
        _coll(mesh, topo, "allreduce", "pip_mcoll", z,
                           codec="zstd")


def test_auto_honors_pinned_codec_at_every_size():
    """algo="auto" with a pinned lossy codec must carry the pin into the
    resolved plan even when the selector's lossless winner is not
    codec-capable (small sizes) — never silently drop it."""
    topo = Topology(4, 2, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    for elems in (16, 1 << 20):
        x = jnp.ones((8, elems), jnp.float32)
        algo, kw = runtime.resolve_algo(topo, "allreduce", "auto", x,
                                        {"codec": "int8_block"})
        assert kw.get("codec") == "int8_block", (elems, algo, kw)
        from repro.core import mcoll
        assert mcoll.supports_codec("allreduce", algo), (elems, algo)


def test_auto_rejects_bad_codec_pins():
    """Invalid codec names and codec pins on non-capable algorithms fail
    at resolution; a pin under auto lands on a codec-capable algorithm
    (every collective has one since compressed broadcast/scatter)."""
    topo = Topology(4, 2)
    x = jnp.ones((8, 64), jnp.float32)
    with pytest.raises(ValueError, match="unknown codec"):
        runtime.resolve_algo(topo, "allreduce", "auto", x, {"codec": "zstd"})
    xb = jnp.ones((64,), jnp.float32)
    with pytest.raises(ValueError, match="does not support compression"):
        runtime.resolve_algo(topo, "broadcast", "binomial", xb,
                             {"codec": "int8_block"})
    algo, kw = runtime.resolve_algo(topo, "broadcast", "auto", xb,
                                    {"codec": "int8_block"})
    from repro.core import mcoll
    assert mcoll.supports_codec("broadcast", algo)
    assert kw.get("codec") == "int8_block"


def test_resolve_auto_zero_budget_is_lossless():
    """auto with the default error_budget resolves every collective to a
    lossless plan (codec absent or "none" in the normalized kwargs)."""
    topo = Topology(1, 1)
    for coll in runtime.collectives():
        x = runtime.example_input(coll, topo, 1 << 22)
        algo, kw = runtime.resolve_algo(topo, coll, "auto", x)
        assert kw.get("codec", "none") == "none", (coll, algo, kw)


def test_calibrate_records_codec_plans(tmp_path):
    """Calibration measures codec variants and records them under plan
    keys; a zero-budget selector ignores them, a budgeted one may use
    them."""
    from repro.core import autotune as at
    mesh, topo = _mesh_topo()
    sel = at.Selector()
    rows = runtime.calibrate(mesh, topo, names=("allreduce",),
                             sizes=(1 << 16,), iters=1, selector=sel)
    assert any(r.codec != "none" for r in rows), "no codec plan measured"
    measured = sel.table.lookup(topo, "allreduce", "float32", 1 << 16)
    assert any(at.decode_plan(k)[2] != "none" for k in measured), measured
    assert sel.choose("allreduce", topo, 1 << 16).codec == "none"
    s = sel.choose("allreduce", topo, 1 << 16, error_budget=1.0)
    assert s.source == "measured"


def test_calibrate_codecs_restrictable():
    """codecs=() keeps a calibration sweep lossless-only."""
    from repro.core import autotune as at
    mesh, topo = _mesh_topo()
    sel = at.Selector()
    rows = runtime.calibrate(mesh, topo, names=("allreduce",),
                             sizes=(256,), iters=1, selector=sel,
                             codecs=())
    assert rows and all(r.codec == "none" for r in rows)


# ---------------------------------------------------------------------------
# LRU bounds: shape-diverse traffic cannot grow the caches without limit
# ---------------------------------------------------------------------------


def test_exec_cache_lru_bounded_and_counts_evictions():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    old = runtime.set_cache_limits()
    runtime.set_cache_limits(max_exec=2)
    try:
        for n in (4, 8, 16):  # 3 distinct shapes through a 2-entry cache
            _coll(mesh, topo, "allgather", "xla",
                               jnp.arange(float(n)))
        s = runtime.cache_stats()
        assert s.exec_misses == 3 and s.exec_evictions == 1
        # oldest entry (n=4) was evicted -> re-miss; newest still hits
        _coll(mesh, topo, "allgather", "xla", jnp.arange(16.0))
        assert runtime.cache_stats().exec_hits == 1
        _coll(mesh, topo, "allgather", "xla", jnp.arange(4.0))
        assert runtime.cache_stats().exec_misses == 4
    finally:
        runtime.set_cache_limits(**{f"max_{k}": v for k, v in old.items()})


def test_exec_cache_lru_recency_order():
    """A hit refreshes recency: the least-recently-USED entry is evicted,
    not the least-recently-inserted."""
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    old = runtime.set_cache_limits()
    runtime.set_cache_limits(max_exec=2)
    try:
        _coll(mesh, topo, "allgather", "xla", jnp.arange(4.0))
        _coll(mesh, topo, "allgather", "xla", jnp.arange(8.0))
        _coll(mesh, topo, "allgather", "xla", jnp.arange(4.0))
        # inserting a third evicts n=8 (LRU), keeping the refreshed n=4
        _coll(mesh, topo, "allgather", "xla", jnp.arange(16.0))
        _coll(mesh, topo, "allgather", "xla", jnp.arange(4.0))
        s = runtime.cache_stats()
        assert s.exec_hits == 2 and s.exec_misses == 3, s
    finally:
        runtime.set_cache_limits(**{f"max_{k}": v for k, v in old.items()})


def test_build_cache_lru_bounded():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    old = runtime.set_cache_limits()
    runtime.set_cache_limits(max_build=2)
    try:
        for algo in ("xla", "pip_mcoll", "ring"):
            runtime.build(mesh, topo, "allgather", algo)
        s = runtime.cache_stats()
        assert s.build_misses == 3 and s.build_evictions == 1
        runtime.build(mesh, topo, "allgather", "xla")  # evicted -> rebuild
        assert runtime.cache_stats().build_misses == 4
    finally:
        runtime.set_cache_limits(**{f"max_{k}": v for k, v in old.items()})


def test_shrinking_limit_evicts_immediately():
    mesh, topo = _mesh_topo()
    runtime.clear_cache()
    old = runtime.set_cache_limits()
    try:
        for n in (4, 8, 16):
            _coll(mesh, topo, "allgather", "xla",
                               jnp.arange(float(n)))
        assert runtime.cache_stats().exec_evictions == 0
        runtime.set_cache_limits(max_exec=1)
        assert runtime.cache_stats().exec_evictions == 2
    finally:
        runtime.set_cache_limits(**{f"max_{k}": v for k, v in old.items()})


# ---------------------------------------------------------------------------
# regression: compat.py is the only module touching the raw API
# ---------------------------------------------------------------------------


def test_no_direct_shard_map_outside_compat():
    pattern = re.compile(
        r"jax\.shard_map|jax\.sharding\.shard_map"
        r"|experimental\.shard_map|experimental import shard_map")
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if path.name == "compat.py":
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if pattern.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{i}: {line.strip()}")
    assert not offenders, (
        "direct shard_map references outside compat.py:\n"
        + "\n".join(offenders))

"""Multi-device correctness for the PiP-MColl collective library, plus
property-based tests on the pure scheduling/cost logic.

Device-parallel checks run in subprocesses (see tests/subproc.py) so the
rest of the suite keeps seeing exactly 1 CPU device.
"""
import math

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import costmodel
from repro.core.mcoll import mo_rounds, _mo_perm
from repro.core.topology import Topology

from subproc import run_check


@pytest.mark.parametrize("n,p", [(4, 3), (3, 4), (2, 6), (5, 2), (8, 2),
                                 (16, 1), (1, 12), (7, 2)])
def test_mcoll_all_collectives(n, p):
    out = run_check("mcoll_check.py", n * p, n, p)
    assert "checks OK" in out


# ---------------------------------------------------------------------------
# property tests: multi-object Bruck schedule invariants
# ---------------------------------------------------------------------------


@given(n=st.integers(2, 4096), radix=st.integers(2, 64))
@settings(max_examples=300, deadline=None)
def test_mo_rounds_cover_exactly(n, radix):
    """The schedule covers exactly N-1 fresh node-blocks, in at most
    ceil(log_B N) + 1 rounds, with strictly growing steps."""
    steps = mo_rounds(n, radix)
    s, covered = 1, 0
    for S in steps:
        assert S == s
        fresh = min((radix - 1) * S, n - s)
        covered += fresh
        s += fresh
    assert covered == n - 1
    assert len(steps) <= math.ceil(math.log(n, radix)) + 1


@given(n=st.integers(2, 64), p=st.integers(1, 32), step=st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_mo_perm_is_valid_permutation(n, p, step):
    """Each round's routing is a bijection on the devices it touches, and
    every lane's source node sits at +offset, dest at -offset."""
    topo = Topology(n, p)
    lanes = min(p, 8)
    pairs = _mo_perm(topo, step % n if step % n else 1, n_lanes=lanes)
    srcs = [a for a, _ in pairs]
    dsts = [b for _, b in pairs]
    assert len(set(srcs)) == len(srcs)
    assert len(set(dsts)) == len(dsts)
    for a, b in pairs:
        na, la = divmod(a, p)
        nb, lb = divmod(b, p)
        assert la == lb  # lanes never cross
        assert nb == (na - (la + 1) * (step % n if step % n else 1)) % n


@given(n=st.integers(2, 256), p=st.integers(1, 32),
       m=st.sampled_from([16, 64, 256, 4096, 1 << 16, 1 << 20]))
@settings(max_examples=200, deadline=None)
def test_allgather_volume_conservation(n, p, m):
    """All algorithms move the same minimum aggregate payload: each node must
    import (N-1)*P*m bytes. Per-NIC totals must be >= that and the
    multi-object total must equal the single-leader total (the paper's
    design trades rounds, not volume)."""
    topo = Topology(n, p)
    net = costmodel.paper_cluster_pip()
    lower = (n - 1) * p * m
    mo = costmodel.allgather_cost("pip_mcoll", topo, m, net)
    sl = costmodel.allgather_cost("single_leader", topo, m, net)
    assert mo.inter_bytes_per_nic >= lower
    assert sl.inter_bytes_per_nic >= lower
    # SPMD padding in multi-lane remainder rounds costs at most 2x; exact
    # when N is a power of the radix.
    assert mo.inter_bytes_per_nic <= 2 * sl.inter_bytes_per_nic
    b = p + 1
    q = n
    while q % b == 0:
        q //= b
    if q == 1:
        assert mo.inter_bytes_per_nic == pytest.approx(lower)
    # fewer (or equal) rounds than the single-object hierarchy
    assert mo.inter_rounds <= sl.inter_rounds


@given(n=st.integers(2, 256), p=st.integers(2, 32))
@settings(max_examples=200, deadline=None)
def test_small_message_latency_win(n, p):
    """In the latency regime (64 B), multi-object must beat the flat
    single-object algorithms the MPI libraries use (the paper's actual
    comparison). Single-leader hierarchy is harder to beat at degenerate
    radices — that's the autotuner's job, not a universal invariant."""
    topo = Topology(n, p)
    net = costmodel.paper_cluster_pip()
    m = 64
    mo = costmodel.allgather_cost("pip_mcoll", topo, m, net)
    rd = costmodel.allgather_cost("recursive_doubling", topo, m, net)
    if mo.inter_rounds + 2 < rd.inter_rounds:  # the regime the paper targets
        assert mo.time < rd.time
    # and with the best radix it at least matches the single-object hierarchy
    # (up to a couple of intra-node hops on degenerate tiny topologies)
    sl = costmodel.allgather_cost("single_leader", topo, m, net)
    best = min(costmodel.allgather_cost("pip_mcoll", topo, m, net, radix=b).time
               for b in range(2, p + 2))
    assert best <= sl.time * 1.05 + 4 * net.alpha_intra


def test_cost_model_brackets_paper_headline():
    """Paper: 4.6x over the best of OpenMPI/MVAPICH2/IntelMPI for 64 B
    allgather on 128 nodes x 18 ppn. We don't know which internal algorithm
    the measured libraries picked at 2304 ranks, so the model must BRACKET
    the measured claim: flat algorithms (default tuning tables at this size)
    put the baseline ~9x behind; a best-case single-leader hierarchical
    baseline puts it ~1.8x behind. 4.6x must fall inside that bracket."""
    topo = Topology(128, 18)
    pip = costmodel.allgather_cost("pip_mcoll", topo, 64,
                                   costmodel.paper_cluster_pip()).time
    lib_nets = (costmodel.paper_cluster_openmpi(),
                costmodel.paper_cluster_cma(),
                costmodel.paper_cluster_posix_shmem())
    flat = min(costmodel.allgather_cost("recursive_doubling", topo, 64, n).time
               for n in lib_nets)
    hier = min(costmodel.allgather_cost("single_leader", topo, 64, n).time
               for n in lib_nets)
    lo, hi = hier / pip, flat / pip
    assert lo <= 4.6 <= hi, (lo, hi)
    assert lo > 1.0, "PiP-MColl must beat even the best-case baseline"


def test_scatter_consistent_win():
    """Paper Fig. 1: PiP-MColl consistently outperforms for small scatter."""
    topo = Topology(128, 18)
    for m in (16, 64, 256, 512):
        pip = costmodel.scatter_cost("pip_mcoll", topo, m,
                                     costmodel.paper_cluster_pip()).time
        other = min(costmodel.scatter_cost("binomial", topo, m, net).time
                    for net in (costmodel.paper_cluster_openmpi(),
                                costmodel.paper_cluster_cma(),
                                costmodel.paper_cluster_posix_shmem()))
        assert pip < other


def test_autotune_prefers_multiobject_small_ring_large():
    topo = Topology(16, 16)
    net = costmodel.tpu_v5e_pod()
    small, _ = __import__("repro.core.autotune", fromlist=["choose"]).choose(
        "allgather", topo, 256, net)
    large, _ = __import__("repro.core.autotune", fromlist=["choose"]).choose(
        "allgather", topo, 1 << 24, net)
    assert small == "pip_mcoll"
    # bandwidth regime: a ring variant — the chunked pipeline once it lands
    assert large in ("xla", "ring", "ring_pipeline")

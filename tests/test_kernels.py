"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all asserting allclose against the pure-jnp ref.py oracles (interpret mode
executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 4, 2, 16), (2, 128, 8, 8, 32), (3, 256, 6, 2, 64),
    (2, 512, 16, 4, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S + hd), 3)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    for cur in (1, S // 3, S):
        got = ops.flash_decode(q, k, v, jnp.int32(cur), chunk=64)
        want = ref.flash_decode(q, k, v, jnp.int32(cur))
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=tol, atol=tol)


@given(b=st.integers(1, 3), nk=st.integers(1, 4), g=st.integers(1, 4),
       hd=st.sampled_from([8, 16, 32]), cur_frac=st.floats(0.1, 1.0))
@settings(max_examples=20, deadline=None)
def test_flash_decode_property(b, nk, g, hd, cur_frac):
    S = 128
    KV = nk
    H = nk * g
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + g), 3)
    q = _rand(ks[0], (b, 1, H, hd), jnp.float32)
    k = _rand(ks[1], (b, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (b, S, KV, hd), jnp.float32)
    cur = max(1, int(S * cur_frac))
    got = ops.flash_decode(q, k, v, jnp.int32(cur), chunk=32)
    want = ref.flash_decode(q, k, v, jnp.int32(cur))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-5,
                               atol=3e-5)


# ---------------------------------------------------------------------------
# rwkv6_wkv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (1, 32, 2, 8, 8), (2, 64, 4, 16, 32), (1, 128, 1, 32, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_wkv_matches_ref(B, T, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(T + hd), 6)
    r = _rand(ks[0], (B, T, H, hd), dtype)
    k = _rand(ks[1], (B, T, H, hd), dtype)
    v = _rand(ks[2], (B, T, H, hd), dtype)
    w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd), jnp.float32)) * 0.98
    u = _rand(ks[4], (H, hd), jnp.float32) * 0.1
    s0 = _rand(ks[5], (B, H, hd, hd), jnp.float32) * 0.1
    y_got, sT_got = ops.rwkv6_wkv(r, k, v, w, u, s0, chunk=chunk)
    y_want, sT_want = ref.rwkv6_wkv(r, k, v, w, u, s0)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(y_got), np.array(y_want), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.array(sT_got), np.array(sT_want), rtol=tol,
                               atol=tol)


@given(t_chunks=st.integers(1, 4), chunk=st.sampled_from([4, 16, 32]),
       hd=st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_rwkv6_chunking_invariance(t_chunks, chunk, hd):
    """Kernel result must not depend on the chunk size (state handoff)."""
    B, H = 1, 2
    T = t_chunks * 32
    ks = jax.random.split(jax.random.PRNGKey(hd + chunk), 6)
    r = _rand(ks[0], (B, T, H, hd), jnp.float32)
    k = _rand(ks[1], (B, T, H, hd), jnp.float32)
    v = _rand(ks[2], (B, T, H, hd), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd), jnp.float32))
    u = _rand(ks[4], (H, hd), jnp.float32) * 0.1
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y1, s1 = ops.rwkv6_wkv(r, k, v, w, u, s0, chunk=chunk)
    y2, s2 = ref.rwkv6_wkv(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.array(s1), np.array(s2), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,Di,N,chunk,dblk", [
    (1, 32, 16, 4, 8, 8), (2, 64, 64, 16, 32, 32), (1, 128, 32, 8, 128, 16),
])
def test_mamba_scan_matches_ref(B, T, Di, N, chunk, dblk):
    ks = jax.random.split(jax.random.PRNGKey(T + Di), 5)
    dt = jax.nn.softplus(_rand(ks[0], (B, T, Di), jnp.float32))
    A = -jnp.exp(_rand(ks[1], (Di, N), jnp.float32) * 0.5)
    Bm = _rand(ks[2], (B, T, N), jnp.float32)
    Cm = _rand(ks[3], (B, T, N), jnp.float32)
    x = _rand(ks[4], (B, T, Di), jnp.float32)
    y_got, h_got = ops.mamba_scan(dt, A, Bm, Cm, x, chunk=chunk, dblk=dblk)
    y_want, h_want = ref.mamba_scan(dt, A, Bm, Cm, x)
    np.testing.assert_allclose(np.array(y_got), np.array(y_want), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(h_got), np.array(h_want), rtol=2e-4,
                               atol=2e-4)


@given(chunk=st.sampled_from([4, 8, 32]), dblk=st.sampled_from([4, 16]))
@settings(max_examples=10, deadline=None)
def test_mamba_scan_block_invariance(chunk, dblk):
    B, T, Di, N = 1, 32, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(chunk * 31 + dblk), 5)
    dt = jax.nn.softplus(_rand(ks[0], (B, T, Di), jnp.float32))
    A = -jnp.exp(_rand(ks[1], (Di, N), jnp.float32) * 0.5)
    Bm = _rand(ks[2], (B, T, N), jnp.float32)
    Cm = _rand(ks[3], (B, T, N), jnp.float32)
    x = _rand(ks[4], (B, T, Di), jnp.float32)
    y_got, h_got = ops.mamba_scan(dt, A, Bm, Cm, x, chunk=chunk, dblk=dblk)
    y_want, h_want = ref.mamba_scan(dt, A, Bm, Cm, x)
    np.testing.assert_allclose(np.array(y_got), np.array(y_want), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# staging kernels (the paper's shared-memory copy analogues)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,m", [(4, 8), (16, 32), (7, 5), (128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_shift_blocks(N, m, dtype):
    v = jnp.arange(N * m).reshape(N, m).astype(dtype)
    for shift in (0, 1, N // 2, N - 1):
        got = ops.shift_blocks(v, jnp.int32(shift))
        want = ref.shift_blocks(v, shift)
        np.testing.assert_array_equal(np.array(got), np.array(want))


@given(n=st.integers(2, 64), k=st.integers(1, 32), seed=st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_pack_blocks_property(n, k, seed):
    m = 4
    src = jnp.arange(n * m, dtype=jnp.float32).reshape(n, m)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (k,), 0, n)
    got = ops.pack_blocks(src, idx)
    want = ref.pack_blocks(src, idx)
    np.testing.assert_array_equal(np.array(got), np.array(want))


# ---------------------------------------------------------------------------
# fused codec kernels (encode+error-feedback / decode+reduce, interpret mode
# on CPU — the same kernel bodies the compressed collectives route through)
# ---------------------------------------------------------------------------


from repro.core import compress  # noqa: E402  (kernel tests below need it)
from repro.kernels import codec as ckern  # noqa: E402

CODEC_SHAPES = [(1, 256), (3, 1000), (4, 64), (2, 2048)]


def _codec_payload(S, L, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (S, L), jnp.float32)
    err = jax.random.normal(k2, (S, L), jnp.float32) * 0.01
    return x, err


def test_codec_lowerings_registered():
    names = ckern.fused_codec_names()
    assert "int8_block" in names and "int4_block" in names
    assert ("fp8_sim" in names) == hasattr(jnp, "float8_e4m3fn")
    # registry agreement: compress advertises exactly what's registered
    assert set(compress.fused_codecs()) == set(names)
    for n in names:
        lw = ckern.lowering(n)
        assert lw is not None and lw.name == n
    assert ckern.lowering("topk") is None


@pytest.mark.parametrize("S,L", CODEC_SHAPES)
@pytest.mark.parametrize("name", ckern.fused_codec_names())
def test_codec_encode_feedback_matches_jnp(name, S, L):
    """Fused one-pass encode+error-feedback vs the jitted jnp reference:
    identical wire form (bitwise), residual to float tolerance."""
    x, err = _codec_payload(S, L, seed=S * 31 + L)
    cd = compress.codec(name)
    lw = ckern.lowering(name)
    with compress.jnp_reference_paths():
        comp_ref, res_ref = jax.jit(cd.encode_with_feedback)(x, err)
    comp_got, res_got = lw.encode_feedback(x, err)
    assert set(comp_got) == set(comp_ref)
    for leaf in comp_ref:
        np.testing.assert_array_equal(np.array(comp_ref[leaf]),
                                      np.array(comp_got[leaf]), err_msg=leaf)
    np.testing.assert_allclose(np.array(res_ref), np.array(res_got),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("S,L", CODEC_SHAPES)
@pytest.mark.parametrize("name", ckern.fused_codec_names())
def test_codec_encode_residual_matches_jnp(name, S, L):
    x, _ = _codec_payload(S, L, seed=S + L)
    cd = compress.codec(name)
    lw = ckern.lowering(name)

    def jnp_ref(x2d):
        comp = cd.encode(x2d)
        return comp, x2d - cd.decode(comp, x2d.shape[-1])

    with compress.jnp_reference_paths():
        comp_ref, res_ref = jax.jit(jnp_ref)(x)
    comp_got, res_got = lw.encode_residual(x)
    for leaf in comp_ref:
        np.testing.assert_array_equal(np.array(comp_ref[leaf]),
                                      np.array(comp_got[leaf]), err_msg=leaf)
    np.testing.assert_allclose(np.array(res_ref), np.array(res_got),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("W", [1, 2, 8])
@pytest.mark.parametrize("name", ckern.fused_codec_names())
def test_codec_decode_reduce_matches_jnp(name, W):
    """Register accumulation over the wire axis vs dequantize-then-sum
    (accumulation order differs, so float tolerance not bitwise)."""
    L = 777
    cd = compress.codec(name)
    xs = jax.random.normal(jax.random.PRNGKey(W), (W, L), jnp.float32)
    comp = cd.encode(xs)
    with compress.jnp_reference_paths():
        want = jax.jit(lambda c: cd.decode(c, L).sum(axis=0))(comp)
    got = ckern.lowering(name).decode_reduce(comp, L)
    assert got.shape == (L,)
    np.testing.assert_allclose(np.array(want), np.array(got),
                               rtol=1e-6, atol=1e-5 * W)


@pytest.mark.parametrize("name", ckern.fused_codec_names())
def test_codec_fused_roundtrip_within_stated_bound(name):
    """decode(fused-encoded wire) honors the codec's stated error bound."""
    cd = compress.codec(name)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 512), jnp.float32)
    comp, res = ckern.lowering(name).encode_residual(x)
    back = cd.decode(comp, 512)
    bound = cd.meta.error_bound * float(jnp.max(jnp.abs(x))) + 1e-6
    assert float(jnp.max(jnp.abs(back - x))) <= bound
    # the residual IS the roundtrip error
    np.testing.assert_allclose(np.array(res), np.array(x - back),
                               rtol=0, atol=1e-6)


def test_codec_int4_wire_is_packed_two_per_byte():
    cd = compress.codec("int4_block")
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 512), jnp.float32)
    comp, _ = ckern.lowering("int4_block").encode_residual(x)
    assert comp["q"].dtype == jnp.uint8
    assert comp["q"].shape == (2, 2, compress.BLOCK // 2)  # half the elems
    # measured wire bytes track the declared ~7.8x ratio
    ratio = x.size * 4 / cd.wire_bytes(comp)
    assert ratio >= 0.9 * cd.meta.wire_ratio


@pytest.mark.parametrize("name", ckern.fused_codec_names())
def test_codec_error_feedback_converges_through_fused_path(name):
    """Carried residual keeps the accumulated signal within one step's
    quantization error of the true accumulation (Karimireddy)."""
    cd = compress.codec(name)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 640), jnp.float32)
    err = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    step = jax.jit(cd.encode_with_feedback)
    for _ in range(50):
        comp, err = step(x, err)
        acc = acc + cd.decode(comp, 640)
    true = 50.0 * x
    # telescoping: acc + err == 50*x up to float roundoff...
    np.testing.assert_allclose(np.array(acc + err), np.array(true),
                               rtol=1e-4, atol=1e-3)
    # ...so the tracking error stays one step's quantization residual,
    # never accumulating over the 50 steps
    bound = cd.meta.error_bound * float(jnp.max(jnp.abs(x))) * 1.5 + 1e-3
    assert float(jnp.max(jnp.abs(acc - true))) <= bound


def test_codec_memory_traffic_fused_at_most_half():
    """The analytic pass accounting behind the cost model's fused pricing:
    encode+feedback moves <= half the jnp path's bytes for every fused
    codec (the ISSUE's acceptance threshold)."""
    for name in ckern.fused_codec_names():
        m = compress.meta(name)
        tr = ckern.memory_traffic(4.0 / m.wire_ratio, 1 << 20, W=8)
        enc = tr["encode_feedback"]
        assert enc["fused_bytes"] <= 0.5 * enc["jnp_bytes"], (name, enc)
        dec = tr["decode_reduce"]
        assert dec["fused_bytes"] < dec["jnp_bytes"], (name, dec)


def test_kernels_integrate_with_layers():
    """use_kernel paths wire correctly into the layers.

    Layer-level: kernel output must be EXACT vs the default path (same
    inputs). Model-level: one-ulp bf16 reassociation inside lax.scan can
    flip discrete MoE top-k routing for a few tokens (verified benign — both
    paths shift equally vs the unscanned reference), so end-to-end we assert
    greedy-token agreement instead of elementwise closeness."""
    from repro.configs import reduced_config
    from repro.layers import mamba, rwkv
    from repro.models import decoder

    # exactness at the layer level
    cfg_m = reduced_config("jamba-1.5-large-398b")
    pm = mamba.init(jax.random.PRNGKey(0), cfg_m)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, 32, cfg_m.d_model)).astype(jnp.bfloat16)
    y_ref, _ = mamba.apply(pm, x, cfg_m, use_kernel=False)
    y_ker, _ = mamba.apply(pm, x, cfg_m, use_kernel=True)
    np.testing.assert_array_equal(np.array(y_ref, np.float32),
                                  np.array(y_ker, np.float32))
    cfg_r = reduced_config("rwkv6-1.6b")
    pr = rwkv.init(jax.random.PRNGKey(0), cfg_r)
    xr = jax.random.normal(jax.random.PRNGKey(2),
                           (2, 32, cfg_r.d_model)).astype(jnp.bfloat16)
    y1, _, s1 = rwkv.time_mix(pr["tm"], xr, cfg_r, use_kernel=False)
    y2, _, s2 = rwkv.time_mix(pr["tm"], xr, cfg_r, use_kernel=True)
    np.testing.assert_array_equal(np.array(y1, np.float32),
                                  np.array(y2, np.float32))

    # wiring through the full models
    for arch in ("rwkv6-1.6b", "jamba-1.5-large-398b"):
        cfg = reduced_config(arch)
        params = decoder.init(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                    cfg.vocab)
        base, _, _ = decoder.forward(params, tokens, cfg)
        flags = decoder.RunFlags(use_rwkv_kernel=True, use_mamba_kernel=True)
        got, _, _ = decoder.forward(params, tokens, cfg, flags=flags)
        b = np.array(base, np.float32)
        g = np.array(got, np.float32)
        agree = (b.argmax(-1) == g.argmax(-1)).mean()
        assert agree > 0.9, (arch, agree)

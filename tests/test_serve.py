"""Serving engine: continuous batching semantics + data pipeline checks."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import SyntheticLM
from repro.models import decoder
from repro.serve.engine import Engine, Request

from subproc import run_check


def test_engine_continuous_batching():
    cfg = reduced_config("smollm-360m")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(n,),
                                        dtype=np.int32), max_new_tokens=4)
            for n in (5, 9, 3, 12, 7)]  # 5 requests through 2 slots
    done = eng.run(reqs)
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) >= 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_greedy_matches_direct_decode():
    """Single request through the engine == manual prefill+decode."""
    cfg = reduced_config("qwen1.5-4b")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(6, dtype=np.int32) + 3
    eng = Engine(params, cfg, max_batch=1, max_len=32)
    out = eng.run([Request(prompt=prompt, max_new_tokens=4)])[0].out_tokens

    import jax.numpy as jnp
    caches = decoder.init_cache(cfg, 1, 32)
    logits, _, caches = decoder.forward(params, jnp.asarray(prompt)[None],
                                        cfg, caches=caches)
    toks = [int(logits[0, -1].argmax())]
    for i in range(3):
        step = len(prompt) + i
        logits, _, caches = decoder.forward(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cfg,
            caches=caches, cache_index=step)
        toks.append(int(logits[0, 0].argmax()))
    assert out == toks, (out, toks)


def test_engine_degenerate_mesh_skips_sync_dispatch():
    """On a world-size-1 mesh there is nothing to reconcile: the engine
    must produce identical tokens WITHOUT dispatching a per-tick
    collective."""
    from repro.core import runtime
    from repro.core.topology import Topology

    cfg = reduced_config("smollm-360m")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(5, dtype=np.int32) + 2
    ref = Engine(params, cfg, max_batch=1, max_len=32)
    want = ref.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]

    mesh = jax.make_mesh((1, 1), ("node", "local"))
    topo = Topology.from_mesh(mesh)
    runtime.clear_cache()
    eng = Engine(params, cfg, max_batch=1, max_len=32, mesh=mesh, topo=topo)
    assert eng.sync_algo == "auto"
    got = eng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])[0]
    assert got.out_tokens == want.out_tokens
    s = runtime.cache_stats()
    assert s.exec_misses == 0 and s.exec_hits == 0, s


def test_engine_mixed_length_admission_matches_solo_runs():
    """Regression for the decode-tick cache-index corruption: a short
    prompt admitted into a batch alongside a longer in-flight sequence
    must decode exactly as it would alone. The broken tick advanced every
    slot at the uniform max cache index, so a freshly admitted short row
    wrote its KV past its true length and attended over uninitialized
    cache — greedy outputs silently diverged from the solo run."""
    cfg = reduced_config("smollm-360m")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=(n,), dtype=np.int32)
               for n in (12, 3, 7)]  # mixed lengths through 2 slots

    def outputs(reqs, max_batch):
        eng = Engine(params, cfg, max_batch=max_batch, max_len=64)
        done = eng.run([Request(prompt=p.copy(), max_new_tokens=6)
                        for p in reqs])
        return {tuple(r.prompt.tolist()): r.out_tokens for r in done}

    solo = {}
    for p in prompts:
        solo.update(outputs([p], max_batch=1))
    batched = outputs(prompts, max_batch=2)
    assert batched == solo, {k: (batched[k], solo[k]) for k in solo
                             if batched[k] != solo[k]}


def test_engine_unscoped_root_mesh_raises_at_construction():
    """A mesh whose axes don't map onto the default node/local topology
    yields an unscoped root communicator; the engine must refuse it in
    __init__ (pointing at sync_axes=) rather than blowing up inside
    broadcast_init on the first multi-replica tick."""
    cfg = reduced_config("smollm-360m")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, 1, 1), ("dp", "tp", "ep"))
    with pytest.raises(ValueError, match=r"sync_axes"):
        Engine(params, cfg, max_batch=1, max_len=32, mesh=mesh)
    # the error's own guidance works: scoping the sync via sync_axes=
    eng = Engine(params, cfg, max_batch=1, max_len=32, mesh=mesh,
                 sync_axes="dp")
    assert eng.sync_comm.topo is not None
    assert eng.sync_comm.topo.world == 1


@pytest.mark.slow
def test_engine_token_sync_resolves_through_selector_2dev():
    """With a real 2-device mesh, every decode tick syncs tokens via the
    Communicator's persistent broadcast op (algo="auto"): same outputs as
    the sync-free engine, selection stats advance, one compile total."""
    out = run_check("serve_sync_check.py", 2, 1, 2)
    assert "serve_sync_check" in out and "OK" in out


@pytest.mark.slow
def test_engine_token_sync_and_metrics_8dev():
    """8-device leg: the same token-sync contract plus Engine.metrics()
    (non-zero tick p50/p99, occupancy, rebind count) and the rebind-storm
    warning, asserted inside the check."""
    out = run_check("serve_sync_check.py", 8, 4, 2)
    assert "serve_sync_check N=4 P=2: OK" in out


def test_data_determinism_and_structure():
    ds = SyntheticLM(vocab=64, seq_len=32, global_batch=4, seed=7)
    b1 = ds.batch(3)
    b2 = ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not (ds.batch(4)["tokens"] == b1["tokens"]).all()
    # next-token alignment
    full1 = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full1[:, 1:], b1["labels"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 64


def test_data_prefetch_iterator():
    ds = SyntheticLM(vocab=64, seq_len=16, global_batch=2, seed=1)
    it = ds.iterator(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch(5)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"], ds.batch(6)["tokens"])

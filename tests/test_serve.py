"""Serving engine: continuous batching semantics + data pipeline checks."""
import jax
import numpy as np

from repro.configs import reduced_config
from repro.data.pipeline import SyntheticLM
from repro.models import decoder
from repro.serve.engine import Engine, Request


def test_engine_continuous_batching():
    cfg = reduced_config("smollm-360m")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(n,),
                                        dtype=np.int32), max_new_tokens=4)
            for n in (5, 9, 3, 12, 7)]  # 5 requests through 2 slots
    done = eng.run(reqs)
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) >= 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_greedy_matches_direct_decode():
    """Single request through the engine == manual prefill+decode."""
    cfg = reduced_config("qwen1.5-4b")
    params = decoder.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(6, dtype=np.int32) + 3
    eng = Engine(params, cfg, max_batch=1, max_len=32)
    out = eng.run([Request(prompt=prompt, max_new_tokens=4)])[0].out_tokens

    import jax.numpy as jnp
    caches = decoder.init_cache(cfg, 1, 32)
    logits, _, caches = decoder.forward(params, jnp.asarray(prompt)[None],
                                        cfg, caches=caches)
    toks = [int(logits[0, -1].argmax())]
    for i in range(3):
        step = len(prompt) + i
        logits, _, caches = decoder.forward(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cfg,
            caches=caches, cache_index=step)
        toks.append(int(logits[0, 0].argmax()))
    assert out == toks, (out, toks)


def test_data_determinism_and_structure():
    ds = SyntheticLM(vocab=64, seq_len=32, global_batch=4, seed=7)
    b1 = ds.batch(3)
    b2 = ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not (ds.batch(4)["tokens"] == b1["tokens"]).all()
    # next-token alignment
    full1 = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full1[:, 1:], b1["labels"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 64


def test_data_prefetch_iterator():
    ds = SyntheticLM(vocab=64, seq_len=16, global_batch=2, seed=1)
    it = ds.iterator(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch(5)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"], ds.batch(6)["tokens"])

"""Roofline machinery: HLO parser units (synthetic HLO), trip-count
weighting on a real compiled scan, analytic model-FLOPs sanity."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import SHAPES, get_config
from repro.roofline import hlo as H
from repro.roofline import terms as T


def test_shape_bytes():
    assert H.shape_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
    assert H.shape_bytes("bf16[2,3]") == 12
    assert H.shape_bytes("(f32[4], s32[2])") == 24
    assert H.shape_bytes("pred[10]") == 10
    assert H.shape_bytes("token[]") == 0


SYNTH = """
HloModule m

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %wl = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1
  %ar = f32[8,8]{1,0} all-reduce(%a), replica_groups={}, to_apply=%cond.1
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%wl), index=1
}
"""


def test_synthetic_hlo_trip_weighting():
    costs = H.analyze(SYNTH)
    # dot inside a 10-trip loop: 2*8*8*8 * 10
    assert costs.flops == 2 * 8 * 8 * 8 * 10
    assert costs.collective_counts.get("all-reduce") == 1
    assert costs.collective_bytes == 8 * 8 * 4


def test_real_compiled_scan_weighting():
    """Compiled lax.scan: parser FLOPs must scale ~linearly with length."""
    w = jnp.ones((32, 32), jnp.float32)
    x = jnp.ones((4, 32), jnp.float32)

    def f(n):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.jit(lambda x: jax.lax.scan(body, x, None, length=n)[0])

    def flops(n):
        txt = f(n).lower(x).compile().as_text()
        return H.analyze(txt).flops

    f4, f16 = flops(4), flops(16)
    assert f4 > 0
    ratio = f16 / f4
    assert 3.0 < ratio < 5.0, (f4, f16)


def test_movement_chain_effective_bytes():
    txt = """
HloModule m

ENTRY %main (a: bf16[1024,64]) -> f32[1024,64] {
  %a = bf16[1024,64]{1,0} parameter(0)
  %c = f32[1024,64]{1,0} convert(%a)
  %cp = f32[1024,64]{1,0} copy(%c)
  %b = f32[64,64]{1,0} constant({...})
  ROOT %d = f32[1024,64]{1,0} dot(%cp, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    costs = H.analyze(txt)
    # dot reads the bf16-effective operand (1024*64*2) + const (64*64*4),
    # writes f32 out; converts/copies contribute nothing
    want = 1024 * 64 * 2 + 64 * 64 * 4 + 1024 * 64 * 4
    assert costs.memory_bytes == want, costs.memory_bytes


@given(arch=st.sampled_from(["yi-34b", "qwen3-moe-235b-a22b", "smollm-360m"]),
       shape=st.sampled_from(["train_4k", "prefill_32k", "decode_32k"]))
@settings(max_examples=12, deadline=None)
def test_model_flops_properties(arch, shape):
    cfg = get_config(arch)
    sc = SHAPES[shape]
    mf = T.model_flops(cfg, sc)
    mfa = T.model_flops_attn(cfg, sc)
    assert mf > 0 and mfa >= 0
    if shape == "train_4k":
        # train >= 3x prefill per token at equal token counts
        pf = T.model_flops(cfg, SHAPES["prefill_32k"])
        tokens_t = sc.global_batch * sc.seq_len
        tokens_p = SHAPES["prefill_32k"].global_batch * \
            SHAPES["prefill_32k"].seq_len
        np.testing.assert_allclose((mf / tokens_t) / (pf / tokens_p), 3.0,
                                   rtol=1e-6)


def test_terms_bottleneck_classification():
    t = T.compute_terms(1e12, 1e12, 1e9, 256, 6e14)
    assert t.bottleneck == "memory"  # 1e12B/819GBps >> 1e12F/197TFs
    t2 = T.compute_terms(1e14, 1e10, 1e9, 256, 6e16)
    assert t2.bottleneck == "compute"

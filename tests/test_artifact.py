"""Schema regression for the benchmark artifact (core/artifact.py).

``results/BENCH_collectives.json`` is assembled by three writers merged in
sequence (``--calibrate``, ``--overlap``, ``--codec-kernels`` driven by
``run.py calibrate``); this suite pins its section/row-key layout so a
writer can't silently drop a section or rename a row key — the exact
failure mode the validator exists for. The mutation tests run against a
synthetic minimal artifact (``results/`` is generated, not committed);
when the generated file is present it is validated too.
"""
import pathlib

import pytest

from repro.core import artifact

REPO = pathlib.Path(__file__).resolve().parent.parent
ARTIFACT = REPO / "results" / "BENCH_collectives.json"


def _minimal():
    """The smallest artifact the full schema accepts: every section, one
    row each, keys exactly as the writers emit them."""
    per_plan = [{"plan": "pip_mcoll", "measured_us": 120.0,
                 "model_us": 80.0, "signed_rel_err": 0.5}]
    return {
        "topology": "4x2/host_cpu/host_cpu",
        "sizes": [256, 4096, 65536],
        "backend": "single",
        "process_count": 1,
        "table": {"version": 1, "entries": {}},
        "latency_rows": [{
            "collective": "allreduce", "algo": "pip_mcoll", "nbytes": 4096,
            "dtype": "float32", "seconds": 1.2e-4, "chunks": 1,
            "codec": "none", "group": ""}],
        "model_vs_measured": [{
            "collective": "allreduce", "nbytes": 4096,
            "measured_algo": "pip_mcoll", "measured_us": 120.0,
            "prior_algo": "pip_mcoll", "prior_us": 80.0, "agree": True,
            "per_plan": per_plan}],
        "pipeline_crossover": [{
            "collective": "allreduce", "algo": "pip_pipeline",
            "model_crossover_bytes": 1 << 20, "model_sweep": [],
            "measured_us_by_plan": {}}],
        "compression": [{
            "codec": "int8_block", "declared_ratio": 3.5,
            "achieved_ratio": 3.4, "stated_rel_bound": 7.9e-3,
            "achieved_abs_error": 1e-4, "bound_abs_tolerance": 2e-4,
            "model_crossover_vs_lossless_bytes": 1 << 16,
            "budget_selection_crossover_bytes": 1 << 16}],
        "overlap": {"devices": 8, "topology": "4x2/host_cpu/host_cpu",
                    "microbench": {}, "amortization": {}, "train_step": {}},
        "codec_kernels": {"devices": 8, "block": 256, "slices": 8,
                          "world": 8, "elems_per_slice": 4096,
                          "fused_codecs": [], "rows": [],
                          "traffic_halved": [], "zlib_sim": {}, "note": ""},
    }


def test_minimal_artifact_validates():
    data = _minimal()
    assert artifact.validate(data) is data
    base = {k: data[k] for k in artifact.CALIBRATE_SECTIONS}
    assert artifact.validate(base, sections=artifact.CALIBRATE_SECTIONS)


def test_every_section_drop_is_caught():
    for section in artifact.ALL_SECTIONS:
        broken = _minimal()
        del broken[section]
        with pytest.raises(artifact.ArtifactError, match=section):
            artifact.validate(broken)


def test_row_key_drop_is_caught():
    for section, keys in artifact.ROW_KEYS.items():
        for key in sorted(keys):
            broken = _minimal()
            del broken[section][0][key]
            with pytest.raises(artifact.ArtifactError, match=key):
                artifact.validate(broken)


def test_per_plan_key_drop_and_emptiness_are_caught():
    for key in sorted(artifact.PER_PLAN_KEYS):
        broken = _minimal()
        del broken["model_vs_measured"][0]["per_plan"][0][key]
        with pytest.raises(artifact.ArtifactError, match=key):
            artifact.validate(broken)
    broken = _minimal()
    broken["model_vs_measured"][0]["per_plan"] = []
    with pytest.raises(artifact.ArtifactError, match="per_plan"):
        artifact.validate(broken)


def test_dict_section_key_drop_is_caught():
    for section, keys in artifact.SECTION_KEYS.items():
        for key in sorted(keys):
            broken = _minimal()
            del broken[section][key]
            with pytest.raises(artifact.ArtifactError):
                artifact.validate(broken)


def test_calibrate_subset_validation():
    data = _minimal()
    base = {k: data[k] for k in artifact.CALIBRATE_SECTIONS}
    # the full-sections default rejects the unmerged artifact
    with pytest.raises(artifact.ArtifactError, match="overlap"):
        artifact.validate(base)
    # present-but-malformed extra sections are rejected even when the
    # required subset is satisfied
    extra = dict(base)
    extra["overlap"] = {"devices": 8}  # missing the other overlap keys
    with pytest.raises(artifact.ArtifactError, match="overlap"):
        artifact.validate(extra, sections=artifact.CALIBRATE_SECTIONS)


def test_malformed_scalars_and_rows_are_caught():
    broken = _minimal()
    broken["sizes"] = []
    with pytest.raises(artifact.ArtifactError, match="sizes"):
        artifact.validate(broken)
    broken = _minimal()
    broken["topology"] = {"nodes": 4}
    with pytest.raises(artifact.ArtifactError, match="topology"):
        artifact.validate(broken)
    broken = _minimal()
    broken["latency_rows"] = "not-a-list"
    with pytest.raises(artifact.ArtifactError, match="latency_rows"):
        artifact.validate(broken)
    broken = _minimal()
    broken["latency_rows"] = []
    with pytest.raises(artifact.ArtifactError, match="latency_rows"):
        artifact.validate(broken)
    for bad_backend in ("", 3, None):
        broken = _minimal()
        broken["backend"] = bad_backend
        with pytest.raises(artifact.ArtifactError, match="backend"):
            artifact.validate(broken)
    for bad_count in (0, -1, "2", 1.5, True):
        broken = _minimal()
        broken["process_count"] = bad_count
        with pytest.raises(artifact.ArtifactError, match="process_count"):
            artifact.validate(broken)


def test_multiprocess_artifact_fields_validate():
    data = _minimal()
    data["backend"] = "multiprocess"
    data["process_count"] = 2
    data["topology"] = "2x4/host_ipc/host_cpu"
    assert artifact.validate(data) is data


@pytest.mark.skipif(not ARTIFACT.exists(),
                    reason="generated artifact not present "
                           "(run benchmarks/run.py calibrate)")
def test_generated_artifact_validates_and_per_plan_is_populated():
    data = artifact.validate_file(ARTIFACT)
    for row in data["model_vs_measured"]:
        assert row["per_plan"], row["collective"]
        plans = {p["plan"] for p in row["per_plan"]}
        # the measured winner appears among the per-plan rows
        assert any(p.startswith(row["measured_algo"]) for p in plans), row
        for p in row["per_plan"]:
            assert p["measured_us"] > 0.0
            if p["model_us"] is not None:
                want = (p["measured_us"] - p["model_us"]) / p["model_us"]
                assert abs(p["signed_rel_err"] - want) < 1e-9

"""Per-architecture smoke tests (reduced configs, 1 CPU device): forward +
train-step + decode shape/NaN checks, plus model-level semantics
(streaming==full attention inside the model, M-RoPE, enc-dec cache paths)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced_config, \
    shape_applicable
from repro.layers import common
from repro.models import decoder, encdec
from repro.models.decoder import RunFlags
from repro.optim import adamw
from repro.train.step import TrainConfig, train_step

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B, T, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab),
             "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, T, cfg.d_model)).astype(jnp.bfloat16) * 0.02
    if cfg.input_mode == "vl":
        batch["embeds"] = jax.random.normal(
            ks[2], (B, T // 4, cfg.d_model)).astype(jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    B, T = 2, 32
    api = encdec if cfg.family == "encdec" else decoder
    params = api.init(KEY, cfg)
    batch = _batch_for(cfg, B, T, KEY)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    tcfg = TrainConfig(optimizer=ocfg, flags=RunFlags(remat="none"))
    opt = adamw.init(params, ocfg)
    new_params, new_opt, metrics = train_step(params, opt, batch, cfg, tcfg)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(new_opt["step"]) == 1
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree.leaves(changed)) > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = reduced_config(arch)
    B, T, MAX = 2, 8, 24
    if cfg.family == "encdec":
        params = encdec.init(KEY, cfg)
        frames = jax.random.normal(KEY, (B, T, cfg.d_model),
                                   jnp.float32).astype(jnp.bfloat16)
        enc_out = encdec.encode(params, frames, cfg)
        xkv = encdec.cross_cache(params, enc_out, cfg)
        caches = encdec.init_cache(cfg, B, MAX)
        tok = jnp.ones((B, 1), jnp.int32)
        for step in range(2):
            logits, caches = encdec.decode_forward(
                params, tok, None, cfg, caches=caches, cache_index=step,
                xkv=xkv)
            assert logits.shape[0] == B and logits.shape[1] == 1
            assert np.isfinite(np.asarray(logits, np.float32)).all()
        return
    params = decoder.init(KEY, cfg)
    caches = decoder.init_cache(cfg, B, MAX)
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    logits, _, caches = decoder.forward(params, tokens, cfg, caches=caches)
    tok = logits[:, -1:].argmax(-1).astype(jnp.int32)
    for step in range(T, T + 2):
        logits, _, caches = decoder.forward(params, tok, cfg, caches=caches,
                                            cache_index=step)
        assert logits.shape[:2] == (B, 1)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = logits.argmax(-1).astype(jnp.int32)


def test_decode_matches_full_forward():
    """Greedy prefill+decode equals the full-sequence forward argmax at each
    position (KV-cache correctness end to end)."""
    cfg = reduced_config("smollm-360m")
    params = decoder.init(KEY, cfg)
    B, T = 1, 12
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    caches = decoder.init_cache(cfg, B, T + 4)
    pre_logits, _, caches = decoder.forward(params, tokens, cfg,
                                            caches=caches)
    full_logits, _, _ = decoder.forward(params, tokens, cfg)
    np.testing.assert_allclose(np.asarray(pre_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
    # decode one more token and compare against extended full forward
    nxt = full_logits[:, -1:].argmax(-1).astype(jnp.int32)
    dec_logits, _, _ = decoder.forward(params, nxt, cfg, caches=caches,
                                       cache_index=T)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    full2, _, _ = decoder.forward(params, ext, cfg)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0], np.float32),
                               np.asarray(full2[:, -1], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_streaming_attention_inside_model():
    """Forcing tiny streaming chunks must not change model outputs."""
    cfg = reduced_config("yi-34b")
    params = decoder.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    base, _, _ = decoder.forward(params, tokens, cfg,
                                 flags=RunFlags(remat="none"))
    import repro.layers.attention as attn
    old = attn.STREAMING_THRESHOLD
    try:
        attn.STREAMING_THRESHOLD = 1  # force streaming path
        got, _, _ = decoder.forward(
            params, tokens, cfg,
            flags=RunFlags(remat="none", q_chunk=16, kv_chunk=32))
    finally:
        attn.STREAMING_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(base, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_mrope_sections_and_equivalence():
    """Text-only M-RoPE must equal standard RoPE (equal position streams)."""
    hd = 64
    pos = jnp.arange(10)[None]
    cos1, sin1 = common.rope_cos_sin(pos, hd, 1e4)
    p3 = common.text_positions3(pos)
    half = hd // 2
    cos2, sin2 = common.mrope_cos_sin(p3, hd, 1e4,
                                      (half // 4, half * 3 // 8,
                                       half * 3 // 8))
    np.testing.assert_allclose(np.array(cos1), np.array(cos2), rtol=1e-6)
    np.testing.assert_allclose(np.array(sin1), np.array(sin2), rtol=1e-6)


def test_long_500k_applicability_matrix():
    """Exactly rwkv6 + jamba run long_500k; all archs run everything else."""
    runs = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        runs[arch] = [s for s in SHAPES
                      if shape_applicable(cfg, SHAPES[s])[0]]
    for arch, shapes in runs.items():
        if arch in ("rwkv6-1.6b", "jamba-1.5-large-398b"):
            assert "long_500k" in shapes, arch
        else:
            assert "long_500k" not in shapes, arch
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_param_counts_match_assignment():
    """Analytic parameter counts must land near the assigned model sizes."""
    expect = {"arctic-480b": 480e9, "qwen3-moe-235b-a22b": 235e9,
              "yi-34b": 34e9, "qwen1.5-4b": 4e9, "phi3-medium-14b": 14e9,
              "smollm-360m": 0.36e9, "jamba-1.5-large-398b": 398e9,
              "rwkv6-1.6b": 1.6e9, "qwen2-vl-72b": 72e9}
    for arch, target in expect.items():
        n = get_config(arch).n_params()
        assert 0.8 * target <= n <= 1.25 * target, (arch, n, target)
    # jamba active ~94B (the A94B in its name)
    assert abs(get_config("jamba-1.5-large-398b").active_params() - 94e9) \
        < 15e9

"""Telemetry: tracer, metrics registry, drift detection, and the
disabled-path invariance guarantees.

Runs on 1-device meshes (degenerate topology); the 8-device acceptance leg
(nested train-step spans in the Perfetto trace, poisoned-table drift +
ingest repair, hot-path overhead guard) is tests/checks/telemetry_check.py.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, runtime, telemetry
from repro.core.comm import Communicator
from repro.core.topology import Topology
from subproc import run_check


@pytest.fixture(autouse=True)
def _telemetry_off():
    """Every test starts (and leaves the process) disabled and empty."""
    telemetry.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


def _mesh_topo():
    mesh = jax.make_mesh((1, 1), ("node", "local"))
    return mesh, Topology(1, 1)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_disabled_tracer_records_nothing_and_allocates_no_context():
    assert not telemetry.enabled()
    ctx = telemetry.span("x", cat="test", plan="p")
    assert ctx is telemetry.span("y")  # shared null context, no allocation
    with ctx:
        pass
    assert telemetry.begin("x") is None
    telemetry.end(None)
    telemetry.emit("x", 0.0, 1.0)
    telemetry.instant("x")
    telemetry.observe_plan(Topology(1, 1), "allreduce", "float32", 64,
                           "pip_mcoll", 1e-3)
    assert telemetry.spans() == []
    assert telemetry.plan_observations() == []
    assert not telemetry.should_sample("k", every=1)


def test_span_and_begin_end_record_tagged_windows():
    telemetry.enable()
    with telemetry.span("build/allreduce", cat="build", plan="pip_mcoll"):
        pass
    tok = telemetry.begin("allreduce[pip_mcoll]", cat="comm",
                          track="comm:allreduce#1", bucket=0)
    telemetry.end(tok)
    s1, s2 = telemetry.spans()
    assert s1.name == "build/allreduce" and s1.track == "main"
    assert dict(s1.args)["plan"] == "pip_mcoll"
    assert s2.track == "comm:allreduce#1" and s2.duration >= 0.0
    assert s2.start >= s1.start


def test_ring_buffer_bounds_and_drop_counter():
    telemetry.enable(capacity=8)
    try:
        for i in range(20):
            telemetry.instant(f"s{i}")
        assert len(telemetry.spans()) == 8
        assert telemetry.spans_dropped() == 12
        assert [s.name for s in telemetry.spans()][0] == "s12"
    finally:
        telemetry.enable(capacity=65536)


def test_export_chrome_trace_tracks_and_events(tmp_path):
    telemetry.enable()
    with telemetry.span("train/step", cat="train"):
        with telemetry.span("train/fwd", cat="train"):
            pass
        tok = telemetry.begin("bucket0[pip_pipeline]", cat="bucket",
                              track="bucket:0")
        telemetry.end(tok)
    out = tmp_path / "trace.json"
    trace = telemetry.export_chrome_trace(out)
    assert json.loads(out.read_text()) == trace
    meta = {e["args"]["name"]: e["tid"] for e in trace["traceEvents"]
            if e["ph"] == "M"}
    assert meta["main"] == 0 and "bucket:0" in meta
    evs = {e["name"]: e for e in trace["traceEvents"] if e["ph"] == "X"}
    assert set(evs) == {"train/step", "train/fwd", "bucket0[pip_pipeline]"}
    step, fwd = evs["train/step"], evs["train/fwd"]
    assert fwd["tid"] == 0 and evs["bucket0[pip_pipeline]"]["tid"] != 0
    # nesting by time containment on the exported microsecond timeline
    assert step["ts"] <= fwd["ts"]
    assert fwd["ts"] + fwd["dur"] <= step["ts"] + step["dur"] + 1e-3
    assert trace["otherData"]["spans_dropped"] == 0


def test_plan_tags_schema():
    tags = telemetry.plan_tags("allreduce", "pip_pipeline", chunks=4,
                               codec="int8_block", group="node", nbytes=5000)
    assert tags == {"collective": "allreduce", "algo": "pip_pipeline",
                    "chunks": 4, "codec": "int8_block", "group": "node",
                    "size_bucket": 8192}
    assert "size_bucket" not in telemetry.plan_tags("broadcast", "binomial")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_histogram_quantiles_and_summary():
    h = telemetry.Histogram("t")
    for v in (1e-3, 2e-3, 3e-3, 4e-3, 100e-3):
        h.observe(v)
    assert h.count == 5 and np.isclose(h.mean, 0.022)
    assert h.vmin == 1e-3 and h.vmax == 100e-3
    assert 1e-3 <= h.quantile(0.5) <= 4e-3
    assert h.quantile(0.99) <= 100e-3
    assert h.quantile(0.0) == 1e-3  # clamped to observed min
    s = h.summary()
    assert s["count"] == 5 and s["p99"] >= s["p50"]
    assert telemetry.Histogram("e").quantile(0.5) == 0.0
    assert telemetry.Histogram("e").summary() == {"count": 0}


def test_registry_counters_always_on_and_reset():
    assert not telemetry.enabled()
    telemetry.counter("x.hits").inc()
    telemetry.counter("x.hits").inc(2)
    telemetry.histogram("x.lat").observe(1e-3)
    d = telemetry.registry().to_dict()
    assert d["counters"]["x.hits"] == 3
    assert d["histograms"]["x.lat"]["count"] == 1
    telemetry.reset()
    assert telemetry.registry().to_dict() == {"counters": {},
                                              "histograms": {}}


# ---------------------------------------------------------------------------
# plan observations + drift detection
# ---------------------------------------------------------------------------


def _observe(topo, plan="pip_mcoll", seconds=(1e-3, 2e-3, 3e-3),
             synced=True, coll="allreduce", nbytes=4096):
    for s in seconds:
        telemetry.observe_plan(topo, coll, "float32", nbytes, plan, s,
                               synced=synced)


def test_observe_plan_median_keeps_sync_and_dispatch_separate():
    telemetry.enable()
    topo = Topology(4, 2)
    _observe(topo, seconds=(1e-3, 2e-3, 3e-3), synced=True)
    _observe(topo, seconds=(1e-6,), synced=False)
    (obs,) = telemetry.plan_observations()
    assert obs.median(synced=True) == 2e-3
    assert obs.median(synced=False) == 1e-6
    reg = telemetry.registry().to_dict()["histograms"]
    assert reg["plan.allreduce.pip_mcoll.sync_seconds"]["count"] == 3
    assert reg["plan.allreduce.pip_mcoll.dispatch_seconds"]["count"] == 1


def test_drift_report_flags_table_divergence_both_directions():
    telemetry.enable()
    topo = Topology(4, 2)
    sel = autotune.Selector(table=autotune.TuningTable())
    # in-band row: table within 1.5x of the observed 2ms median
    _observe(topo, plan="pip_mcoll", seconds=(2e-3,) * 3)
    sel.table.record(topo, "allreduce", "float32", 4096, "pip_mcoll", 1.5e-3)
    # poisoned-fast row: table claims 1000x faster than observed
    _observe(topo, plan="ring", seconds=(2e-3,) * 3)
    sel.table.record(topo, "allreduce", "float32", 4096, "ring", 2e-6)
    # poisoned-slow row: table claims 1000x slower than observed
    _observe(topo, plan="recursive_doubling", seconds=(2e-3,) * 3)
    sel.table.record(topo, "allreduce", "float32", 4096,
                     "recursive_doubling", 2.0)
    rows = {r.plan: r for r in telemetry.drift_report(selector=sel)}
    assert not rows["pip_mcoll"].flagged
    assert rows["ring"].flagged and rows["ring"].drift_vs_table > 0
    assert rows["recursive_doubling"].flagged
    assert rows["recursive_doubling"].drift_vs_table < 0
    # worst-first ordering and the flagged-only view agree
    report = telemetry.drift_report(selector=sel)
    assert abs(report[0].drift_vs_table) >= abs(report[-1].drift_vs_table)
    assert {r.plan for r in telemetry.drifted_plans(selector=sel)} == \
        {"ring", "recursive_doubling"}


def test_drift_report_without_table_entry_reports_model_only():
    telemetry.enable()
    topo = Topology(4, 2)
    _observe(topo, plan="pip_mcoll", seconds=(2e-3,) * 3)
    (row,) = telemetry.drift_report(selector=autotune.Selector(
        table=autotune.TuningTable()))
    assert row.table_s is None and row.drift_vs_table is None
    assert not row.flagged  # no table promise -> nothing to flag
    assert row.model_s is not None and row.drift_vs_model is not None


def test_drift_report_min_samples_gate():
    telemetry.enable()
    topo = Topology(4, 2)
    _observe(topo, seconds=(2e-3,))
    sel = autotune.Selector(table=autotune.TuningTable())
    assert telemetry.drift_report(selector=sel, min_samples=2) == []
    assert len(telemetry.drift_report(selector=sel, min_samples=1)) == 1


def test_selector_ingest_folds_observed_medians_into_table():
    telemetry.enable()
    topo = Topology(4, 2)
    _observe(topo, plan="pip_mcoll", seconds=(1e-3, 2e-3, 3e-3))
    _observe(topo, plan="ring", seconds=(5e-3,))
    sel = autotune.Selector(table=autotune.TuningTable())
    gen0 = sel.table.generation
    assert sel.ingest(telemetry, min_samples=2) == 1  # ring gated out
    entry = sel.table.lookup(topo, "allreduce", "float32", 4096)
    assert entry == {"pip_mcoll": 2e-3}
    assert sel.table.generation > gen0
    assert sel.ingest(telemetry, min_samples=1) == 2  # both qualify now
    assert sel.table.lookup(topo, "allreduce", "float32",
                            4096)["ring"] == 5e-3


def test_should_sample_is_deterministic_one_in_n():
    telemetry.enable()
    hits = [telemetry.should_sample("k", every=4) for _ in range(8)]
    assert hits == [True, False, False, False, True, False, False, False]


# ---------------------------------------------------------------------------
# disabled-path invariance: telemetry must never change results or caching
# ---------------------------------------------------------------------------


def _run_all(comm, topo):
    outs = {}
    for name in runtime.collectives():
        x = runtime.example_input(name, topo, 256)
        outs[name] = np.asarray(comm.invoke(name, x))
    return outs


def test_outputs_and_exec_cache_keys_invariant_under_telemetry():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    base = _run_all(comm, topo)
    keys_off = set(runtime._EXEC_CACHE)
    telemetry.enable()
    runtime.clear_cache()
    traced = _run_all(comm, topo)
    keys_on = set(runtime._EXEC_CACHE)
    assert keys_on == keys_off, "telemetry state leaked into cache keys"
    for name, out in base.items():
        np.testing.assert_array_equal(out, traced[name], err_msg=name)
    assert len(telemetry.spans()) > 0  # it did actually trace


def test_persistent_op_bitwise_invariant_and_sampled_probe_gated():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    x = jnp.arange(64, dtype=jnp.float32).reshape(1, 64)
    op = comm.allreduce_init(x, algo="pip_mcoll")
    off = np.asarray(op.start(x).wait())
    telemetry.enable()
    on = np.asarray(op.start(x).wait())
    np.testing.assert_array_equal(off, on)
    # the start->wait window landed as a comm span with plan tags
    comm_spans = [s for s in telemetry.spans() if s.cat == "comm"]
    assert comm_spans and dict(comm_spans[-1].args)["algo"] == "pip_mcoll"
    (obs,) = [o for o in telemetry.plan_observations()
              if o.collective == "allreduce"]
    assert len(obs.samples) == 1  # blocking wait -> one synced sample


def test_snapshot_unifies_observables_when_disabled():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    comm.allreduce(jnp.ones((1, 16), jnp.float32))
    snap = telemetry.snapshot()
    assert snap["enabled"] is False
    assert snap["tracer"]["spans"] == 0
    assert snap["cache"]["exec_misses"] >= 1
    assert snap["selection"]["total"] >= 1
    assert isinstance(snap["live_persistent_ops"], int)
    assert snap["plans"] == []


def test_cache_stats_reset_zeroes_in_place():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    comm.allreduce(jnp.ones((1, 16), jnp.float32))
    s = runtime.cache_stats()
    assert s.exec_misses >= 1
    s.reset()
    assert runtime.cache_stats().exec_misses == 0
    assert runtime.cache_stats().exec_hits == 0


# ---------------------------------------------------------------------------
# 8-device acceptance leg (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_telemetry_acceptance_8dev():
    """Nested train-step spans in the exported trace, poisoned-table drift
    flagged + repaired by Selector.ingest, hot-path overhead < 2%."""
    out = run_check("telemetry_check.py", 8, 4, 2)
    assert "telemetry_check N=4 P=2: OK" in out

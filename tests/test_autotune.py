"""Selection subsystem: candidate coverage, crossover behavior, measured
calibration beating priors, tuning-table persistence, topology link
metadata, and the 8-device algo="auto" equivalence check."""
import jax
import numpy as np
import pytest

from repro.core import autotune, costmodel, mcoll
from repro.core.autotune import Selector, TuningTable
from repro.core.topology import Topology, derive_link

from subproc import run_check

SIX = ("allgather", "scatter", "broadcast", "allreduce", "reduce_scatter",
       "alltoall")

# algorithms whose latency scales with round count (log-ish), vs the
# bandwidth-optimal ones that win at large sizes (the chunked pipelines
# belong to the bandwidth regime: chunking amortizes round latency)
LOW_ROUND = {"pip_mcoll", "recursive_doubling", "bruck", "binomial",
             "single_leader", "linear"}
BANDWIDTH = {"xla", "ring", "ring_pipeline", "pip_pipeline"}


# ---------------------------------------------------------------------------
# candidate registry: full coverage, no drift from mcoll
# ---------------------------------------------------------------------------


def test_candidates_cover_every_implemented_algorithm():
    """Regression for the old _CANDIDATES gaps (bruck missing, three
    collectives absent): candidates == the mcoll registry."""
    for coll in SIX:
        assert autotune.candidates(coll) == tuple(mcoll.algorithms(coll))


def test_cost_fns_cover_every_candidate():
    """Every registered algorithm has a cost-model branch."""
    topo = Topology(4, 4)
    for coll in SIX:
        fn = costmodel.COST_FNS[coll]
        for algo in autotune.candidates(coll, topo):
            c = fn(algo, topo, 1024, costmodel.tpu_v5e_pod())
            assert c.time > 0, (coll, algo)


def test_recursive_doubling_filtered_on_non_pow2():
    assert "recursive_doubling" not in autotune.candidates(
        "allgather", Topology(3, 2))
    assert "recursive_doubling" in autotune.candidates(
        "allgather", Topology(4, 2))


# ---------------------------------------------------------------------------
# crossover: small -> low-round, large -> bandwidth-optimal, no oscillation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll", SIX)
def test_crossover_monotone_small_latency_large_bandwidth(coll):
    topo = Topology(16, 16, node_link="tpu_v5e_ici", local_link="tpu_v5e_ici")
    sel = Selector()
    table = sel.crossover_table(coll, topo)
    sizes = sorted(table)
    assert table[sizes[0]].algo in LOW_ROUND, (coll, table[sizes[0]])
    assert table[sizes[-1]].algo in (BANDWIDTH if coll != "scatter"
                                     else LOW_ROUND | BANDWIDTH), coll
    # monotone: once a bandwidth-optimal algorithm wins, larger sizes never
    # fall back to a latency-bound one
    seen_bandwidth = False
    for s in sizes:
        if table[s].algo in BANDWIDTH:
            seen_bandwidth = True
        elif seen_bandwidth:
            pytest.fail(f"{coll}: crossover oscillated at {s}B "
                        f"-> {table[s].algo}")


def test_choose_small_prefers_multiobject_on_paper_cluster():
    topo = Topology(128, 18, node_link="pip", local_link="pip")
    sel = Selector()
    s = sel.choose("allgather", topo, 64)
    assert s.algo == "pip_mcoll" and s.source == "prior"
    assert s.chunks == 1, "latency regime must not chunk"


def test_choose_large_plans_chunked_pipeline():
    """The bandwidth regime resolves to a chunked pipelined plan: the
    chunk count is part of the selection, >1 only where the model says
    pipelining pays (the crossover vs. the unchunked variant)."""
    topo = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    sel = Selector()
    small = sel.choose("allreduce", topo, 256)
    assert small.chunks == 1, small
    large = sel.choose("allreduce", topo, 1 << 24)
    assert large.algo == "pip_pipeline" and large.chunks > 1, large
    net = costmodel.net_for(topo)
    unchunked = costmodel.allreduce_cost("pip_pipeline", topo, 1 << 24, net,
                                         chunks=1).time
    assert large.seconds < unchunked, "chunked plan must beat unchunked"


def test_measured_chunked_plan_decodes():
    """A measured plan key ("algo#cN") resolves to (algo, chunks)."""
    topo = Topology(4, 2)
    sel = Selector()
    sel.table.record(topo, "allreduce", "float32", 1 << 20, "xla", 1e-3)
    sel.table.record(topo, "allreduce", "float32", 1 << 20,
                     autotune.encode_plan("pip_pipeline", 8), 1e-6)
    s = sel.choose("allreduce", topo, 1 << 20)
    assert (s.algo, s.chunks, s.source) == ("pip_pipeline", 8, "measured")


# ---------------------------------------------------------------------------
# measured calibration beats the prior; stats track sources
# ---------------------------------------------------------------------------


def test_measured_entry_overrides_prior_and_counts():
    topo = Topology(4, 2)
    sel = Selector()
    prior = sel.choose("allgather", topo, 256)
    assert prior.source == "prior"
    # fake calibration: "ring" measured fastest in the 256B bucket
    for algo in autotune.candidates("allgather", topo):
        sel.table.record(topo, "allgather", "float32", 256, algo,
                         1e-6 if algo == "ring" else 1e-3)
    s = sel.choose("allgather", topo, 200)  # same bucket (pow2 ceiling)
    assert s.algo == "ring" and s.source == "measured"
    # other dtypes / buckets still fall back to the prior
    assert sel.choose("allgather", topo, 1 << 20).source == "prior"
    assert sel.choose("allgather", topo, 256, dtype="bfloat16").source == \
        "prior"
    assert sel.stats.measured == 1 and sel.stats.prior == 3
    assert 0 < sel.stats.measured_fraction < 1
    assert sel.stats.by_choice[("allgather", "ring")] == 1


def test_measured_entry_ignored_when_infeasible():
    """A measurement for an algorithm that is infeasible on this topology
    (recursive_doubling on non-pow2) must not be selected."""
    topo = Topology(3, 2)
    sel = Selector()
    sel.table.record(topo, "allreduce", "float32", 256,
                     "recursive_doubling", 1e-9)
    sel.table.record(topo, "allreduce", "float32", 256, "xla", 1e-3)
    s = sel.choose("allreduce", topo, 256)
    assert s.algo == "xla" and s.source == "measured"


# ---------------------------------------------------------------------------
# tuning table persistence
# ---------------------------------------------------------------------------


def test_tuning_table_json_round_trip(tmp_path):
    topo = Topology(4, 2, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    t = TuningTable()
    t.record(topo, "allgather", "float32", 256, "pip_mcoll", 1.5e-6)
    t.record(topo, "allgather", "float32", 200, "ring", 2.5e-6)  # same bucket
    t.record(topo, "alltoall", "bfloat16", 4096, "xla", 9e-6)
    path = tmp_path / "table.json"
    t.save(path)
    t2 = TuningTable.load(path)
    assert t2.entries == t.entries
    assert len(t2) == len(t) == 3
    assert t2.lookup(topo, "allgather", "float32", 250) == {
        "pip_mcoll": 1.5e-6, "ring": 2.5e-6}
    # a selector loading the file resolves from measurement
    sel = Selector()
    sel.load_table(path)
    assert sel.choose("allgather", topo, 256).source == "measured"


def test_tuning_table_version_gate(tmp_path):
    with pytest.raises(ValueError):
        TuningTable.from_json({"version": 999, "entries": {}})


def test_tuning_table_keys_include_links():
    ici = Topology(4, 2, node_link="tpu_v5e_ici", local_link="tpu_v5e_ici")
    dcn = Topology(4, 2, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    t = TuningTable()
    t.record(ici, "allgather", "float32", 256, "xla", 1e-6)
    assert t.lookup(dcn, "allgather", "float32", 256) is None, \
        "different link metadata must not share measurements"


def test_memo_invalidated_by_new_measurements():
    topo = Topology(4, 2)
    sel = Selector()
    first = sel.choose("allgather", topo, 256)
    assert first.source == "prior"
    for algo in autotune.candidates("allgather", topo):
        sel.table.record(topo, "allgather", "float32", 256, algo,
                         1e-6 if algo == "ring" else 1e-3)
    assert sel.choose("allgather", topo, 256).source == "measured"


# ---------------------------------------------------------------------------
# topology link metadata -> cost-model parameterisation
# ---------------------------------------------------------------------------


def test_net_for_composes_per_axis_links():
    topo = Topology(2, 256, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    net = costmodel.net_for(topo)
    dcn, ici = costmodel.tpu_v5e_multipod(), costmodel.tpu_v5e_pod()
    assert net.alpha_inter == dcn.alpha_inter
    assert net.beta_inter == dcn.beta_inter
    assert net.alpha_intra == ici.alpha_intra
    assert net.beta_intra == ici.beta_intra
    assert "tpu_v5e_dcn" in net.name and "tpu_v5e_ici" in net.name


def test_net_for_defaults_and_overrides():
    assert costmodel.net_for(Topology(4, 2)).name == "tpu_v5e_dcn"
    override = costmodel.paper_cluster_pip()
    topo = Topology(4, 2, node_link=override, local_link=override)
    assert costmodel.net_for(topo) == override
    with pytest.raises(ValueError):
        costmodel.resolve_net("no_such_preset")


def test_from_mesh_derives_host_cpu_links():
    mesh = jax.make_mesh((1, 1), ("node", "local"))
    topo = Topology.from_mesh(mesh)
    assert topo.link_names == ("host_cpu", "host_cpu")
    assert derive_link(mesh, "node", "inter") == "host_cpu"
    assert costmodel.net_for(topo).name == "host_cpu"
    # explicit links win over derivation
    topo2 = Topology.from_mesh(mesh, node_link="tpu_v5e_dcn")
    assert topo2.link_names == ("tpu_v5e_dcn", "host_cpu")


def test_back_compat_choose_and_tuning_table():
    topo = Topology(16, 16)
    net = costmodel.tpu_v5e_pod()
    algo, t = autotune.choose("allgather", topo, 256, net)
    assert algo == "pip_mcoll" and t > 0
    table = autotune.tuning_table("allgather", topo, net)
    assert set(table) == {2 ** i for i in range(4, 27)}
    assert all(isinstance(a, str) for a in table.values())


# ---------------------------------------------------------------------------
# the real thing: algo="auto" on an 8-device mesh matches every explicit
# algorithm, and calibration flips resolution to the measured table
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_auto_equivalence_and_calibration_8dev():
    out = run_check("auto_check.py", 8, 4, 2)
    assert "auto_check" in out and "OK" in out

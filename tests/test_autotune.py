"""Selection subsystem: candidate coverage, crossover behavior, measured
calibration beating priors, tuning-table persistence, topology link
metadata, error-budget codec gating, and the 8-device algo="auto"
equivalence check."""
import jax
import numpy as np
import pytest

from repro.core import autotune, compress, costmodel, mcoll
from repro.core.autotune import Selector, TuningTable
from repro.core.topology import Topology, derive_link

from subproc import run_check

SIX = ("allgather", "scatter", "broadcast", "allreduce", "reduce_scatter",
       "alltoall")

# algorithms whose latency scales with round count (log-ish), vs the
# bandwidth-optimal ones that win at large sizes (the chunked pipelines
# belong to the bandwidth regime: chunking amortizes round latency)
LOW_ROUND = {"pip_mcoll", "recursive_doubling", "bruck", "binomial",
             "single_leader", "linear"}
BANDWIDTH = {"xla", "ring", "ring_pipeline", "pip_pipeline"}


# ---------------------------------------------------------------------------
# candidate registry: full coverage, no drift from mcoll
# ---------------------------------------------------------------------------


def test_candidates_cover_every_implemented_algorithm():
    """Regression for the old _CANDIDATES gaps (bruck missing, three
    collectives absent): candidates == the mcoll registry."""
    for coll in SIX:
        assert autotune.candidates(coll) == tuple(mcoll.algorithms(coll))


def test_cost_fns_cover_every_candidate():
    """Every registered algorithm has a cost-model branch."""
    topo = Topology(4, 4)
    for coll in SIX:
        fn = costmodel.COST_FNS[coll]
        for algo in autotune.candidates(coll, topo):
            c = fn(algo, topo, 1024, costmodel.tpu_v5e_pod())
            assert c.time > 0, (coll, algo)


def test_recursive_doubling_filtered_on_non_pow2():
    assert "recursive_doubling" not in autotune.candidates(
        "allgather", Topology(3, 2))
    assert "recursive_doubling" in autotune.candidates(
        "allgather", Topology(4, 2))


# ---------------------------------------------------------------------------
# crossover: small -> low-round, large -> bandwidth-optimal, no oscillation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll", SIX)
def test_crossover_monotone_small_latency_large_bandwidth(coll):
    topo = Topology(16, 16, node_link="tpu_v5e_ici", local_link="tpu_v5e_ici")
    sel = Selector()
    table = sel.crossover_table(coll, topo)
    sizes = sorted(table)
    assert table[sizes[0]].algo in LOW_ROUND, (coll, table[sizes[0]])
    assert table[sizes[-1]].algo in (BANDWIDTH if coll != "scatter"
                                     else LOW_ROUND | BANDWIDTH), coll
    # monotone: once a bandwidth-optimal algorithm wins, larger sizes never
    # fall back to a latency-bound one
    seen_bandwidth = False
    for s in sizes:
        if table[s].algo in BANDWIDTH:
            seen_bandwidth = True
        elif seen_bandwidth:
            pytest.fail(f"{coll}: crossover oscillated at {s}B "
                        f"-> {table[s].algo}")


def test_choose_small_prefers_multiobject_on_paper_cluster():
    topo = Topology(128, 18, node_link="pip", local_link="pip")
    sel = Selector()
    s = sel.choose("allgather", topo, 64)
    assert s.algo == "pip_mcoll" and s.source == "prior"
    assert s.chunks == 1, "latency regime must not chunk"


def test_choose_large_plans_chunked_pipeline():
    """The bandwidth regime resolves to a chunked pipelined plan: the
    chunk count is part of the selection, >1 only where the model says
    pipelining pays (the crossover vs. the unchunked variant)."""
    topo = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    sel = Selector()
    small = sel.choose("allreduce", topo, 256)
    assert small.chunks == 1, small
    large = sel.choose("allreduce", topo, 1 << 24)
    assert large.algo == "pip_pipeline" and large.chunks > 1, large
    net = costmodel.net_for(topo)
    unchunked = costmodel.allreduce_cost("pip_pipeline", topo, 1 << 24, net,
                                         chunks=1).time
    assert large.seconds < unchunked, "chunked plan must beat unchunked"


def test_measured_chunked_plan_decodes():
    """A measured plan key ("algo#cN") resolves to (algo, chunks)."""
    topo = Topology(4, 2)
    sel = Selector()
    sel.table.record(topo, "allreduce", "float32", 1 << 20, "xla", 1e-3)
    sel.table.record(topo, "allreduce", "float32", 1 << 20,
                     autotune.encode_plan("pip_pipeline", 8), 1e-6)
    s = sel.choose("allreduce", topo, 1 << 20)
    assert (s.algo, s.chunks, s.source) == ("pip_pipeline", 8, "measured")


# ---------------------------------------------------------------------------
# error budget: codec plan gating (the accuracy contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("coll", SIX)
def test_zero_budget_provably_never_lossy(coll):
    """With error_budget=0.0 (the default) the selector can never emit a
    lossy plan: (a) candidate enumeration admits only "none", (b) a full
    size sweep on every topology class resolves codec="none", (c) even a
    poisoned tuning table with fast lossy measurements cannot leak one."""
    for algo in autotune.candidates(coll):
        assert autotune.codec_candidates(coll, algo, 0.0) == ("none",)
    for topo in (Topology(16, 16, node_link="tpu_v5e_dcn",
                          local_link="tpu_v5e_ici"),
                 Topology(128, 18, node_link="pip", local_link="pip"),
                 Topology(1, 8), Topology(4, 2)):
        sel = Selector()
        for i in range(4, 27):
            s = sel.choose(coll, topo, 1 << i)  # default budget: 0.0
            assert s.codec == "none", (coll, topo, 1 << i, s)
    # poisoned table: lossy plan measured fastest in the bucket
    topo = Topology(4, 2)
    sel = Selector()
    for algo in autotune.candidates(coll, topo):
        if mcoll.supports_codec(coll, algo):
            sel.table.record(topo, coll, "float32", 1 << 20,
                             autotune.encode_plan(algo, 1, "topk"), 1e-12)
    sel.table.record(topo, coll, "float32", 1 << 20, "xla", 1e-3)
    s = sel.choose(coll, topo, 1 << 20)
    assert s.codec == "none", s
    # ... while a permissive budget may use the measured lossy entry
    if any(mcoll.supports_codec(coll, a)
           for a in autotune.candidates(coll, topo)):
        s2 = sel.choose(coll, topo, 1 << 20, error_budget=1.0)
        assert s2.codec == "topk" and s2.source == "measured", s2


def test_budget_admits_codecs_and_compressed_wins_bandwidth_regime():
    """Under a budget, the large-message prior resolves to a codec plan
    that strictly beats the lossless plan; the admitted codec respects the
    bound ordering (tighter budget -> tighter codec)."""
    topo = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    sel = Selector()
    lossless = sel.choose("allreduce", topo, 1 << 24)
    b_int8 = compress.meta("int8_block").error_bound
    s = sel.choose("allreduce", topo, 1 << 24, error_budget=b_int8)
    assert s.codec == "int8_block", s
    assert s.seconds < lossless.seconds
    s2 = sel.choose("allreduce", topo, 1 << 24, error_budget=1.0)
    assert s2.codec != "none"
    assert s2.seconds <= s.seconds
    # small messages stay lossless even under an unlimited budget: the
    # codec flop cost cannot buy anything in the latency-bound regime
    small = sel.choose("allreduce", topo, 64, error_budget=1.0)
    assert small.codec == "none", small


def test_budget_is_part_of_the_memo_key():
    """The same (collective, size) resolved under different budgets must
    not share memoized Selections."""
    topo = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    sel = Selector()
    a = sel.choose("allreduce", topo, 1 << 24, error_budget=0.0)
    b = sel.choose("allreduce", topo, 1 << 24, error_budget=1.0)
    assert a.codec == "none" and b.codec != "none"
    assert sel.choose("allreduce", topo, 1 << 24).codec == "none"


def test_measured_codec_plan_decodes_and_respects_budget():
    """A measured "algo#cN@codec" plan resolves to its full triple under an
    admitting budget, and is filtered under a tighter one."""
    topo = Topology(4, 2)
    sel = Selector()
    sel.table.record(topo, "allreduce", "float32", 1 << 20, "xla", 1e-3)
    sel.table.record(
        topo, "allreduce", "float32", 1 << 20,
        autotune.encode_plan("pip_pipeline", 8, "int8_block"), 1e-6)
    s = sel.choose("allreduce", topo, 1 << 20,
                   error_budget=compress.meta("int8_block").error_bound)
    assert (s.algo, s.chunks, s.codec, s.source) == \
        ("pip_pipeline", 8, "int8_block", "measured")
    tight = sel.choose("allreduce", topo, 1 << 20, error_budget=1e-6)
    assert tight.codec == "none" and tight.algo == "xla"


def test_unknown_codec_in_table_skipped():
    """A table recorded by a build with extra codecs must not crash or be
    selected — unknown codec names are skipped."""
    topo = Topology(4, 2)
    sel = Selector()
    sel.table.record(topo, "allreduce", "float32", 256,
                     "pip_mcoll@future_codec", 1e-12)
    sel.table.record(topo, "allreduce", "float32", 256, "xla", 1e-3)
    s = sel.choose("allreduce", topo, 256, error_budget=1.0)
    assert s.algo == "xla" and s.source == "measured"


def test_integer_dtypes_force_lossless_resolution():
    """auto with a positive budget on integer/bool payloads must resolve
    lossless (the compressed execution rejects integer payloads, so the
    selector must never plan one) — including from a poisoned table."""
    topo = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    sel = Selector()
    for dt in ("int32", "int8", "uint8", "bool"):
        s = sel.choose("allreduce", topo, 1 << 24, dtype=dt,
                       error_budget=1.0)
        assert s.codec == "none", (dt, s)
    # float dtypes are unaffected
    assert sel.choose("allreduce", topo, 1 << 24, dtype="bfloat16",
                      error_budget=1.0).codec != "none"
    t2 = Topology(4, 2)
    sel2 = Selector()
    sel2.table.record(t2, "allreduce", "int32", 1 << 20,
                      autotune.encode_plan("pip_mcoll", 1, "topk"), 1e-12)
    sel2.table.record(t2, "allreduce", "int32", 1 << 20, "xla", 1e-3)
    s = sel2.choose("allreduce", t2, 1 << 20, dtype="int32",
                    error_budget=1.0)
    assert s.codec == "none" and s.algo == "xla"


def test_codec_candidates_only_for_capable_algorithms():
    assert autotune.codec_candidates("allreduce", "xla", 1.0) == ("none",)
    assert autotune.codec_candidates("broadcast", "xla", 1.0) == ("none",)
    bcast = autotune.codec_candidates("broadcast", "pip_mcoll", 1.0)
    assert bcast[0] == "none" and set(compress.lossy()) <= set(bcast)
    cands = autotune.codec_candidates("allreduce", "pip_mcoll", 1.0)
    assert cands[0] == "none" and set(compress.lossy()) <= set(cands)


def test_codec_candidates_integer_payloads():
    """Lossy codecs never appear for integer payloads; the lossless packer
    does — but only on non-reducing collectives."""
    bcast = autotune.codec_candidates("broadcast", "pip_mcoll", 1.0,
                                      dtype="int32")
    assert "zlib_sim" in bcast
    assert not set(compress.lossy()) & set(bcast)
    ar = autotune.codec_candidates("allreduce", "pip_mcoll", 1.0,
                                   dtype="int32")
    assert ar == ("none",)
    f32 = autotune.codec_candidates("broadcast", "pip_mcoll", 0.0)
    assert "zlib_sim" not in f32  # integer-only packer stays off floats


def test_plan_cost_prices_codec_wire_and_flops():
    """plan_cost scales the wire beta by the codec ratio and adds the flop
    term: compressed is cheaper at bandwidth-bound sizes, costlier at
    latency-bound ones."""
    topo = Topology(16, 16, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    net = costmodel.net_for(topo)
    big_l = costmodel.plan_cost("allreduce", "pip_mcoll", topo, 1 << 24, net)
    big_c = costmodel.plan_cost("allreduce", "pip_mcoll", topo, 1 << 24,
                                net, codec="int8_block")
    assert big_c.time < big_l.time
    assert big_c.inter_bytes_per_nic < big_l.inter_bytes_per_nic
    tiny_l = costmodel.plan_cost("allreduce", "pip_mcoll", topo, 16, net)
    tiny_c = costmodel.plan_cost("allreduce", "pip_mcoll", topo, 16, net,
                                 codec="int8_block")
    assert tiny_c.time >= tiny_l.time * 0.999  # flops >= wire savings
    xo = costmodel.compressed_crossover_bytes("allreduce", "pip_pipeline",
                                              topo, net, "int8_block")
    assert xo is not None and xo >= 64


# ---------------------------------------------------------------------------
# measured calibration beats the prior; stats track sources
# ---------------------------------------------------------------------------


def test_measured_entry_overrides_prior_and_counts():
    topo = Topology(4, 2)
    sel = Selector()
    prior = sel.choose("allgather", topo, 256)
    assert prior.source == "prior"
    # fake calibration: "ring" measured fastest in the 256B bucket
    for algo in autotune.candidates("allgather", topo):
        sel.table.record(topo, "allgather", "float32", 256, algo,
                         1e-6 if algo == "ring" else 1e-3)
    s = sel.choose("allgather", topo, 200)  # same bucket (pow2 ceiling)
    assert s.algo == "ring" and s.source == "measured"
    # other dtypes / buckets still fall back to the prior
    assert sel.choose("allgather", topo, 1 << 20).source == "prior"
    assert sel.choose("allgather", topo, 256, dtype="bfloat16").source == \
        "prior"
    assert sel.stats.measured == 1 and sel.stats.prior == 3
    assert 0 < sel.stats.measured_fraction < 1
    assert sel.stats.by_choice[("allgather", "ring")] == 1


def test_measured_entry_ignored_when_infeasible():
    """A measurement for an algorithm that is infeasible on this topology
    (recursive_doubling on non-pow2) must not be selected."""
    topo = Topology(3, 2)
    sel = Selector()
    sel.table.record(topo, "allreduce", "float32", 256,
                     "recursive_doubling", 1e-9)
    sel.table.record(topo, "allreduce", "float32", 256, "xla", 1e-3)
    s = sel.choose("allreduce", topo, 256)
    assert s.algo == "xla" and s.source == "measured"


# ---------------------------------------------------------------------------
# tuning table persistence
# ---------------------------------------------------------------------------


def test_tuning_table_json_round_trip(tmp_path):
    topo = Topology(4, 2, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    t = TuningTable()
    t.record(topo, "allgather", "float32", 256, "pip_mcoll", 1.5e-6)
    t.record(topo, "allgather", "float32", 200, "ring", 2.5e-6)  # same bucket
    t.record(topo, "alltoall", "bfloat16", 4096, "xla", 9e-6)
    path = tmp_path / "table.json"
    t.save(path)
    t2 = TuningTable.load(path)
    assert t2.entries == t.entries
    assert len(t2) == len(t) == 3
    assert t2.lookup(topo, "allgather", "float32", 250) == {
        "pip_mcoll": 1.5e-6, "ring": 2.5e-6}
    # a selector loading the file resolves from measurement
    sel = Selector()
    sel.load_table(path)
    assert sel.choose("allgather", topo, 256).source == "measured"


def test_tuning_table_version_gate(tmp_path):
    with pytest.raises(ValueError):
        TuningTable.from_json({"version": 999, "entries": {}})


def test_tuning_table_keys_include_links():
    ici = Topology(4, 2, node_link="tpu_v5e_ici", local_link="tpu_v5e_ici")
    dcn = Topology(4, 2, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    t = TuningTable()
    t.record(ici, "allgather", "float32", 256, "xla", 1e-6)
    assert t.lookup(dcn, "allgather", "float32", 256) is None, \
        "different link metadata must not share measurements"


def test_memo_invalidated_by_new_measurements():
    topo = Topology(4, 2)
    sel = Selector()
    first = sel.choose("allgather", topo, 256)
    assert first.source == "prior"
    for algo in autotune.candidates("allgather", topo):
        sel.table.record(topo, "allgather", "float32", 256, algo,
                         1e-6 if algo == "ring" else 1e-3)
    assert sel.choose("allgather", topo, 256).source == "measured"


# ---------------------------------------------------------------------------
# topology link metadata -> cost-model parameterisation
# ---------------------------------------------------------------------------


def test_net_for_composes_per_axis_links():
    topo = Topology(2, 256, node_link="tpu_v5e_dcn", local_link="tpu_v5e_ici")
    net = costmodel.net_for(topo)
    dcn, ici = costmodel.tpu_v5e_multipod(), costmodel.tpu_v5e_pod()
    assert net.alpha_inter == dcn.alpha_inter
    assert net.beta_inter == dcn.beta_inter
    assert net.alpha_intra == ici.alpha_intra
    assert net.beta_intra == ici.beta_intra
    assert "tpu_v5e_dcn" in net.name and "tpu_v5e_ici" in net.name


def test_net_for_defaults_and_overrides():
    assert costmodel.net_for(Topology(4, 2)).name == "tpu_v5e_dcn"
    override = costmodel.paper_cluster_pip()
    topo = Topology(4, 2, node_link=override, local_link=override)
    assert costmodel.net_for(topo) == override
    with pytest.raises(ValueError):
        costmodel.resolve_net("no_such_preset")


def test_from_mesh_derives_host_cpu_links():
    mesh = jax.make_mesh((1, 1), ("node", "local"))
    topo = Topology.from_mesh(mesh)
    assert topo.link_names == ("host_cpu", "host_cpu")
    assert derive_link(mesh, "node", "inter") == "host_cpu"
    assert costmodel.net_for(topo).name == "host_cpu"
    # explicit links win over derivation
    topo2 = Topology.from_mesh(mesh, node_link="tpu_v5e_dcn")
    assert topo2.link_names == ("tpu_v5e_dcn", "host_cpu")


def test_back_compat_choose_and_tuning_table():
    topo = Topology(16, 16)
    net = costmodel.tpu_v5e_pod()
    algo, t = autotune.choose("allgather", topo, 256, net)
    assert algo == "pip_mcoll" and t > 0
    table = autotune.tuning_table("allgather", topo, net)
    assert set(table) == {2 ** i for i in range(4, 27)}
    assert all(isinstance(a, str) for a in table.values())


# ---------------------------------------------------------------------------
# the real thing: algo="auto" on an 8-device mesh matches every explicit
# algorithm, and calibration flips resolution to the measured table
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_auto_equivalence_and_calibration_8dev():
    out = run_check("auto_check.py", 8, 4, 2)
    assert "auto_check" in out and "OK" in out

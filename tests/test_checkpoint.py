"""Fault tolerance: atomic checkpointing, failure injection + exact resume,
elastic re-shard restore."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
            "step": jnp.int32(7)}
    mgr.save(5, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = mgr.restore(5, like)
    np.testing.assert_array_equal(
        np.array(out["a"]["w"], np.float32),
        np.array(tree["a"]["w"], np.float32))
    assert int(out["step"]) == 7
    assert mgr.latest_step() == 5


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_atomic_commit_no_partial(tmp_path):
    """A .tmp dir left behind must never be picked up as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    (tmp_path / ".tmp_step_000000009").mkdir()
    assert mgr.latest_step() is None
    mgr.save(3, {"w": jnp.ones(2)})
    assert mgr.latest_step() == 3


def _run_train(args, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        capture_output=True, text=True, env=env, timeout=600, check=False)


@pytest.mark.slow
def test_failure_injection_and_exact_resume(tmp_path):
    """Kill training at step 7, resume from the step-5 checkpoint, and the
    final losses must be bitwise-identical to an uninterrupted run
    (deterministic data + state restore)."""
    common = ["--arch", "smollm-360m", "--reduced", "--steps", "12",
              "--batch", "2", "--seq", "32", "--ckpt-every", "5",
              "--log-every", "1", "--lr", "1e-3", "--ckpt-blocking"]
    # uninterrupted reference
    ref = _run_train(common + ["--ckpt-dir", str(tmp_path / "ref")])
    assert ref.returncode == 0, ref.stdout + ref.stderr
    # interrupted run
    crash = _run_train(common + ["--ckpt-dir", str(tmp_path / "ft"),
                                 "--die-at-step", "7"])
    assert crash.returncode == 42, crash.stdout + crash.stderr
    assert "injected failure" in crash.stdout
    resumed = _run_train(common + ["--ckpt-dir", str(tmp_path / "ft")])
    assert resumed.returncode == 0, resumed.stdout + resumed.stderr
    assert "resumed from step 5" in resumed.stdout

    def losses(out):
        return {int(l.split()[2]): l.split()[4]
                for l in out.splitlines() if l.startswith("[train] step")}
    ref_l = losses(ref.stdout)
    res_l = losses(resumed.stdout)
    for step in (10, 11):
        assert ref_l[step] == res_l[step], (step, ref_l, res_l)


def test_elastic_reshard_restore(tmp_path):
    """Save unsharded, restore onto an explicit sharding target (the elastic
    path: same bytes, new topology/placement)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    out = mgr.restore(1, jax.tree.map(jnp.zeros_like, tree),
                      shardings=shardings)
    np.testing.assert_array_equal(np.array(out["w"]), np.array(tree["w"]))
    assert out["w"].sharding == shardings["w"]

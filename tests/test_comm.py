"""Communicator API: blocking methods, persistent nonblocking ops, the
plan-spec normalization point, the memoized per-(mesh, topo) communicator,
the runtime.collective deprecation shim, and the repo-wide grep enforcing
that no call site outside the shim invokes the free function.

Runs on 1-device meshes (degenerate topology) — multi-device behavior is
covered by tests/test_conformance.py and the subprocess checks.
"""
import pathlib
import re
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm as comm_mod
from repro.core import mcoll, runtime
from repro.core.comm import Communicator, PersistentOp, PlanSpec
from repro.core.topology import Topology

REPO = pathlib.Path(__file__).resolve().parent.parent


def _mesh_topo(node="node", local="local"):
    mesh = jax.make_mesh((1, 1), (node, local))
    return mesh, Topology(1, 1, node_axis=node, local_axis=local)


# ---------------------------------------------------------------------------
# blocking methods
# ---------------------------------------------------------------------------


def test_methods_cover_every_collective():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    for name in runtime.collectives():
        assert callable(getattr(comm, name)), name
        assert callable(getattr(comm, f"{name}_init")), name
        x = runtime.example_input(name, topo, 64)
        out = comm.invoke(name, x)
        assert np.isfinite(np.asarray(out, np.float64)).all()


def test_method_matches_runtime_backend_bitwise():
    """A Communicator method and the runtime backend entry are one code
    path — identical results, shared exec-cache entry."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    a = comm.allreduce(z, algo="pip_mcoll")
    b = runtime.run(mesh, topo, "allreduce", "pip_mcoll", z)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1, s


def test_unknown_collective_rejected():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    with pytest.raises(ValueError, match="unknown collective"):
        comm.invoke("gossip", jnp.arange(4.0))


def test_kwargs_validated_at_plan_construction():
    """An unsupported knob fails with a clear ValueError when the plan is
    constructed — never a TypeError mid-trace."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    z = jnp.ones((1, 16), jnp.float32)
    with pytest.raises(ValueError, match="unsupported kwargs"):
        comm.allreduce(z, algo="xla", radix=3)
    with pytest.raises(ValueError, match="unknown algorithm"):
        comm.allreduce(z, algo="does_not_exist")
    with pytest.raises(ValueError, match="does not support chunking"):
        comm.allreduce(z, algo="xla", chunks=2)
    with pytest.raises(ValueError, match="does not support compression"):
        comm.allreduce(z, algo="xla", codec="int8_block")
    # a knob pinned in the spec AND passed again as an extra kwarg is a
    # contradiction the resolver refuses (internal API: methods make this
    # unreachable by construction)
    with pytest.raises(ValueError, match="duplicate plan knobs"):
        comm._resolve(PlanSpec("allreduce", "pip_pipeline", chunks=2), z,
                      {"chunks": 3})


def test_plan_resolution_method():
    """comm.plan exposes the selector's (algo, chunks, codec) plan for
    shard-body consumers (MoE) on this communicator's topology."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    sel = comm.plan("allreduce", 1 << 20)
    assert sel.algo in mcoll.algorithms("allreduce")
    assert sel.chunks >= 1 and sel.codec == "none"


def test_instance_selector_drives_auto_resolution():
    """A Communicator constructed with its own selector resolves auto
    plans (blocking AND persistent) through IT, not the process default —
    its calibration data is actually consulted."""
    from repro.core import autotune
    mesh, topo = _mesh_topo()
    custom = autotune.Selector()
    comm = Communicator(mesh, topo, selector=custom)
    z = jnp.ones((1, 64), jnp.float32)
    default_before = autotune.default_selector().stats.total
    comm.allreduce(z)                   # algo="auto" -> custom selector
    comm.allreduce_init(z)              # persistent init resolves too
    assert custom.stats.total == 2, custom.stats
    assert autotune.default_selector().stats.total == default_before
    # a measured entry recorded into the custom table wins its resolution
    custom.table.record(topo, "allreduce", "float32", 256, "xla", 1e-9)
    algo, _ = runtime.resolve_algo(topo, "allreduce", "auto", z,
                                   selector=custom)
    assert algo == "xla"
    op = comm.allreduce_init(z)
    assert op.algo == "xla", op.plan


# ---------------------------------------------------------------------------
# the memoized communicator + the deprecation shim
# ---------------------------------------------------------------------------


def test_communicator_memoized_per_mesh_topo():
    mesh, topo = _mesh_topo()
    c1 = comm_mod.communicator(mesh, topo)
    c2 = comm_mod.communicator(mesh, topo)
    assert c1 is c2
    mesh2, topo2 = _mesh_topo("n2", "l2")
    assert comm_mod.communicator(mesh2, topo2) is not c1


def test_shim_warns_once_and_is_bit_identical():
    """runtime.collective survives as a deprecation shim: exactly one
    DeprecationWarning per process, results bit-identical to the method,
    cache entries shared."""
    mesh, topo = _mesh_topo()
    comm = comm_mod.communicator(mesh, topo)
    z = jnp.ones((1, 32), jnp.float32)
    want = np.asarray(comm.allreduce(z, algo="pip_mcoll"))
    runtime._SHIM_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got1 = runtime.collective(mesh, topo, "allreduce", "pip_mcoll", z)
        got2 = runtime.collective(mesh, topo, "allreduce", "pip_mcoll", z)
    assert [x for x in w if x.category is DeprecationWarning], \
        "shim must warn"
    assert len([x for x in w if x.category is DeprecationWarning]) == 1, \
        "shim must warn exactly once"
    np.testing.assert_array_equal(np.asarray(got1), want)
    np.testing.assert_array_equal(np.asarray(got2), want)


def test_shim_shares_cache_entries_with_methods():
    mesh, topo = _mesh_topo()
    comm = comm_mod.communicator(mesh, topo)
    runtime.clear_cache()
    z = jnp.ones((1, 48), jnp.float32)
    comm.allreduce(z, algo="pip_mcoll")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        runtime.collective(mesh, topo, "allreduce", "pip_mcoll", z)
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1, s


# ---------------------------------------------------------------------------
# persistent ops (1-device semantics; multi-device in conformance/checks)
# ---------------------------------------------------------------------------


def test_persistent_op_properties_and_call():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    z = jnp.ones((1, 64), jnp.float32)
    op = comm.allreduce_init(z, algo="pip_pipeline", chunks=2)
    assert isinstance(op, PersistentOp)
    assert (op.algo, op.chunks, op.codec) == ("pip_pipeline", 2, "none")
    assert op.plan == "pip_pipeline#c2"
    assert op.shape == (1, 64) and op.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(op(z)),  # __call__ sugar
                                  np.asarray(comm.allreduce(
                                      z, algo="pip_pipeline", chunks=2)))


def test_persistent_init_needs_an_operand_spec():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    with pytest.raises(ValueError, match="shape"):
        comm.allreduce_init()
    op = comm.allreduce_init(shape=(1, 8), dtype=jnp.float32,
                             algo="pip_mcoll")
    out = op.start(jnp.ones((1, 8), jnp.float32)).wait()
    np.testing.assert_array_equal(np.asarray(out), np.ones((1, 8)))


def test_persistent_init_resolves_auto_once():
    """auto resolves at init; the op then carries a concrete plan."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    z = jnp.ones((1, 1 << 18), jnp.float32)
    op = comm.allreduce_init(z)  # algo="auto"
    assert op.algo != "auto" and op.algo in mcoll.algorithms("allreduce")
    algo, kw = runtime.resolve_algo(topo, "allreduce", "auto", z)
    assert op.algo == algo and op.chunks == kw.get("chunks", 1)


def test_persistent_depth_validation():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    with pytest.raises(ValueError, match="depth"):
        comm.allreduce_init(shape=(1, 8), dtype=jnp.float32,
                            algo="pip_mcoll", depth=0)


def test_persistent_donate_is_a_distinct_program():
    """donate=True compiles a separate executable (input aliasing differs)
    but produces identical results."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    z = jnp.ones((1, 32), jnp.float32)
    op = comm.allreduce_init(z, algo="pip_mcoll")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # CPU may ignore donation
        opd = comm.allreduce_init(z, algo="pip_mcoll", donate=True)
        want = np.asarray(op.start(z).wait())
        got = np.asarray(opd.start(jnp.ones((1, 32), jnp.float32)).wait())
    np.testing.assert_array_equal(got, want)
    assert runtime.cache_stats().exec_misses == 2


# ---------------------------------------------------------------------------
# regression grep: the shim is the ONLY runtime.collective call site
# ---------------------------------------------------------------------------


def test_no_runtime_collective_call_sites_outside_shim():
    """Like the PR-1 shard_map grep: after the Communicator migration, no
    code anywhere in the repo invokes the deprecated free function —
    except its definition (core/runtime.py) and this file's shim tests."""
    pattern = re.compile(
        r"runtime\.collective\s*\(|"
        r"from\s+repro\.core\.runtime\s+import\s+.*\bcollective\b")
    allowed = {
        REPO / "src" / "repro" / "core" / "runtime.py",
        pathlib.Path(__file__).resolve(),
    }
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for path in sorted((REPO / sub).rglob("*.py")):
            if path.resolve() in allowed:
                continue
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if pattern.search(line):
                    offenders.append(
                        f"{path.relative_to(REPO)}:{i}: {line.strip()}")
    assert not offenders, (
        "runtime.collective call sites outside the deprecation shim "
        "(migrate to repro.core.comm.Communicator):\n"
        + "\n".join(offenders))


# ---------------------------------------------------------------------------
# PlanSpec normalization (unit level; cache-entry assertions live in
# test_runtime.py::test_exec_cache_kwargs_normalization_single_entry)
# ---------------------------------------------------------------------------


def test_plan_spec_kwargs_drop_unpinned_knobs():
    assert PlanSpec("allreduce").kwargs() == {}
    assert PlanSpec("allreduce", chunks=None, codec=None).kwargs() == {}
    assert PlanSpec("allreduce", chunks=4).kwargs() == {"chunks": 4}
    assert PlanSpec("allreduce", codec="none").kwargs() == {"codec": "none"}
    assert PlanSpec("allreduce", chunk_bytes=1024).kwargs() == \
        {"chunk_bytes": 1024}


def test_plan_spec_normalized_resolution_is_single_plan():
    """Every spelling of the default plan resolves to identical normalized
    kwargs — the exec-cache key material."""
    topo = Topology(1, 1)
    z = jnp.ones((1, 64), jnp.float32)
    resolved = set()
    for spec in (PlanSpec("allreduce", "pip_pipeline"),
                 PlanSpec("allreduce", "pip_pipeline", chunks=1),
                 PlanSpec("allreduce", "pip_pipeline", chunks=None),
                 PlanSpec("allreduce", "pip_pipeline", codec="none"),
                 PlanSpec("allreduce", "pip_pipeline", codec=None)):
        algo, kw = runtime.resolve_algo(topo, spec.collective, spec.algo, z,
                                        spec.kwargs())
        resolved.add((algo, tuple(sorted(kw.items()))))
    assert len(resolved) == 1, resolved

"""Communicator API: blocking methods, persistent nonblocking ops, the
plan-spec normalization point, the memoized per-(mesh, topo) communicator,
comm.split() sub-communicators, and the repo-wide grep enforcing that the
retired free-function shims (runtime.collective, mcoll.collective_fn) stay
gone.

Runs on 1-device meshes (degenerate topology) — multi-device behavior is
covered by tests/test_conformance.py and the subprocess checks.
"""
import pathlib
import re
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm as comm_mod
from repro.core import mcoll, runtime
from repro.core.comm import Communicator, PersistentOp, PlanSpec
from repro.core.topology import Topology

REPO = pathlib.Path(__file__).resolve().parent.parent


def _mesh_topo(node="node", local="local"):
    mesh = jax.make_mesh((1, 1), (node, local))
    return mesh, Topology(1, 1, node_axis=node, local_axis=local)


# ---------------------------------------------------------------------------
# blocking methods
# ---------------------------------------------------------------------------


def test_methods_cover_every_collective():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    for name in runtime.collectives():
        assert callable(getattr(comm, name)), name
        assert callable(getattr(comm, f"{name}_init")), name
        x = runtime.example_input(name, topo, 64)
        out = comm.invoke(name, x)
        assert np.isfinite(np.asarray(out, np.float64)).all()


def test_method_matches_runtime_backend_bitwise():
    """A Communicator method and the runtime backend entry are one code
    path — identical results, shared exec-cache entry."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    z = jnp.ones((1, 64), jnp.float32)
    a = comm.allreduce(z, algo="pip_mcoll")
    b = runtime.run(mesh, topo, "allreduce", "pip_mcoll", z)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1, s


def test_unknown_collective_rejected():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    with pytest.raises(ValueError, match="unknown collective"):
        comm.invoke("gossip", jnp.arange(4.0))


def test_kwargs_validated_at_plan_construction():
    """An unsupported knob fails with a clear ValueError when the plan is
    constructed — never a TypeError mid-trace."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    z = jnp.ones((1, 16), jnp.float32)
    with pytest.raises(ValueError, match="unsupported kwargs"):
        comm.allreduce(z, algo="xla", radix=3)
    with pytest.raises(ValueError, match="unknown algorithm"):
        comm.allreduce(z, algo="does_not_exist")
    with pytest.raises(ValueError, match="does not support chunking"):
        comm.allreduce(z, algo="xla", chunks=2)
    with pytest.raises(ValueError, match="does not support compression"):
        comm.allreduce(z, algo="xla", codec="int8_block")
    # a knob pinned in the spec AND passed again as an extra kwarg is a
    # contradiction the resolver refuses (internal API: methods make this
    # unreachable by construction)
    with pytest.raises(ValueError, match="duplicate plan knobs"):
        comm._resolve(PlanSpec("allreduce", "pip_pipeline", chunks=2), z,
                      {"chunks": 3})


def test_plan_resolution_method():
    """comm.plan exposes the selector's (algo, chunks, codec) plan for
    shard-body consumers (MoE) on this communicator's topology."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    sel = comm.plan("allreduce", 1 << 20)
    assert sel.algo in mcoll.algorithms("allreduce")
    assert sel.chunks >= 1 and sel.codec == "none"


def test_instance_selector_drives_auto_resolution():
    """A Communicator constructed with its own selector resolves auto
    plans (blocking AND persistent) through IT, not the process default —
    its calibration data is actually consulted."""
    from repro.core import autotune
    mesh, topo = _mesh_topo()
    custom = autotune.Selector()
    comm = Communicator(mesh, topo, selector=custom)
    z = jnp.ones((1, 64), jnp.float32)
    default_before = autotune.default_selector().stats.total
    comm.allreduce(z)                   # algo="auto" -> custom selector
    comm.allreduce_init(z)              # persistent init resolves too
    assert custom.stats.total == 2, custom.stats
    assert autotune.default_selector().stats.total == default_before
    # a measured entry recorded into the custom table wins its resolution
    custom.table.record(topo, "allreduce", "float32", 256, "xla", 1e-9)
    algo, _ = runtime.resolve_algo(topo, "allreduce", "auto", z,
                                   selector=custom)
    assert algo == "xla"
    op = comm.allreduce_init(z)
    assert op.algo == "xla", op.plan


# ---------------------------------------------------------------------------
# the memoized communicator
# ---------------------------------------------------------------------------


def test_communicator_memoized_per_mesh_topo():
    mesh, topo = _mesh_topo()
    c1 = comm_mod.communicator(mesh, topo)
    c2 = comm_mod.communicator(mesh, topo)
    assert c1 is c2
    mesh2, topo2 = _mesh_topo("n2", "l2")
    assert comm_mod.communicator(mesh2, topo2) is not c1


# ---------------------------------------------------------------------------
# comm.split(): sub-communicator edge cases (1-device; multi-device group
# semantics live in tests/test_conformance.py)
# ---------------------------------------------------------------------------


def test_split_memoized_and_shares_selector():
    """Repeated splits of one spec return the SAME child (so persistent
    ops and plan caches are shared), and children share the parent's
    selector so calibration merges into one table."""
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    g1 = root.split(axes="local")
    g2 = root.split(axes="local")
    assert g1 is g2
    assert g1.selector is root.selector
    assert g1.topo.group == "local" and g1.topo.world == 1
    assert root.split(axes="node") is not g1


def test_split_world1_and_size1_axes_run_collectives():
    """Degenerate groups (size-1 axis -> world-1 child) still run every
    collective: the identity semantics, not an error."""
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    g = root.split(axes="local")
    z = jnp.ones((1, 16), jnp.float32)
    np.testing.assert_array_equal(np.asarray(g.allreduce(z)),
                                  np.asarray(z))
    for name in runtime.collectives():
        x = runtime.example_input(name, g.topo, 64)
        out = g.invoke(name, x)
        assert np.isfinite(np.asarray(out, np.float64)).all()


def test_single_axis_group_topology_dedupes_axes():
    """A single-axis group names the same mesh axis at both topology
    levels; ``active_axes`` must still name it once — a repeated axis in
    the collective tuple is a trace-time ppermute error on real meshes."""
    topo = Topology(1, 4, node_axis="tp", local_axis="tp")
    assert topo.active_axes == ("tp",)
    assert Topology(1, 1, node_axis="tp", local_axis="tp").active_axes \
        == ("tp",)


def test_split_of_split_composes():
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    gg = root.split(axes=("node", "local")).split(axes="local")
    assert gg.topo.world == 1 and gg.topo.group == "local"
    z = jnp.ones((1, 8), jnp.float32)
    np.testing.assert_array_equal(np.asarray(gg.allreduce(z)),
                                  np.asarray(z))


def test_split_exec_cache_shared_between_identical_children():
    """Two identically-specced splits (memo hit) reuse one exec-cache
    entry — the group topology is the cache key, not the child object."""
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    runtime.clear_cache()
    z = jnp.ones((1, 32), jnp.float32)
    root.split(axes="local").allreduce(z, algo="pip_mcoll")
    root.split(axes="local").allreduce(z, algo="pip_mcoll")
    s = runtime.cache_stats()
    assert s.exec_misses == 1 and s.exec_hits == 1, s


def test_split_group_namespaces_tuning_keys():
    """A child's tuning rows carry the group tag: the same NxP shape tuned
    as a group never aliases the ungrouped table rows (an 8-way TP group
    and an 8-way flat world calibrate independently)."""
    from repro.core import autotune
    mesh, topo = _mesh_topo()
    root = Communicator(mesh, topo)
    g = root.split(axes="local")
    assert autotune.topo_key(g.topo) != autotune.topo_key(topo)
    assert autotune.topo_key(g.topo).endswith("/g:local")
    root.selector.table.record(g.topo, "allreduce", "float32", 1 << 10,
                               "xla", 1e-9)
    assert root.selector.table.lookup(topo, "allreduce", "float32",
                                      1 << 10) is None
    sel = g.plan("allreduce", 1 << 10)
    assert sel.algo == "xla"


def test_split_calibration_table_roundtrip_with_group_keys(tmp_path):
    """Group-keyed rows survive a save/load cycle and keep resolving."""
    from repro.core import autotune
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    g = root.split(axes="local")
    root.selector.table.record(g.topo, "allreduce", "float32", 1 << 10,
                               "xla", 1e-9)
    path = tmp_path / "table.json"
    root.selector.table.save(path)
    loaded = autotune.TuningTable.load(path)
    hit = loaded.lookup(g.topo, "allreduce", "float32", 1 << 10)
    assert hit == {"xla": 1e-9}


def test_split_validation():
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    with pytest.raises(ValueError, match="exactly one of"):
        root.split()
    with pytest.raises(ValueError, match="exactly one of"):
        root.split(axes="local", color=[0])
    with pytest.raises(ValueError, match="key= only"):
        root.split(axes="local", key=[0])
    with pytest.raises(ValueError, match="not in mesh axes"):
        root.split(axes="tp")
    with pytest.raises(ValueError, match="one entry per parent rank"):
        root.split(color=[0, 1])


def test_split_color_groups():
    mesh, _ = _mesh_topo()
    root = Communicator(mesh)
    groups = root.split(color=[7])
    assert set(groups) == {7}
    g = groups[7]
    assert g.topo.world == 1 and g.topo.group == "color7"
    z = jnp.ones((1, 8), jnp.float32)
    np.testing.assert_array_equal(np.asarray(g.allreduce(z)), np.asarray(z))


def test_unscoped_root_requires_split():
    """A mesh without the node/local axes yields an unscoped root:
    split(axes=...) works, collectives raise with a pointer to it."""
    mesh = jax.make_mesh((1,), ("tp",))
    root = Communicator(mesh)
    assert root.topo is None
    with pytest.raises(ValueError, match=r"split\(axes=\.\.\.\)"):
        root.allreduce(jnp.ones((1, 8), jnp.float32))
    with pytest.raises(ValueError, match=r"split\(axes=\.\.\.\)"):
        root.plan("allreduce", 1 << 10)
    g = root.split(axes="tp")
    assert g.topo is not None and g.topo.world == 1
    z = jnp.ones((1, 8), jnp.float32)
    np.testing.assert_array_equal(np.asarray(g.allreduce(z)), np.asarray(z))


# ---------------------------------------------------------------------------
# persistent ops (1-device semantics; multi-device in conformance/checks)
# ---------------------------------------------------------------------------


def test_persistent_op_properties_and_call():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    z = jnp.ones((1, 64), jnp.float32)
    op = comm.allreduce_init(z, algo="pip_pipeline", chunks=2)
    assert isinstance(op, PersistentOp)
    assert (op.algo, op.chunks, op.codec) == ("pip_pipeline", 2, "none")
    assert op.plan == "pip_pipeline#c2"
    assert op.shape == (1, 64) and op.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(op(z)),  # __call__ sugar
                                  np.asarray(comm.allreduce(
                                      z, algo="pip_pipeline", chunks=2)))


def test_persistent_init_needs_an_operand_spec():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    with pytest.raises(ValueError, match="shape"):
        comm.allreduce_init()
    op = comm.allreduce_init(shape=(1, 8), dtype=jnp.float32,
                             algo="pip_mcoll")
    out = op.start(jnp.ones((1, 8), jnp.float32)).wait()
    np.testing.assert_array_equal(np.asarray(out), np.ones((1, 8)))


def test_persistent_init_resolves_auto_once():
    """auto resolves at init; the op then carries a concrete plan."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    z = jnp.ones((1, 1 << 18), jnp.float32)
    op = comm.allreduce_init(z)  # algo="auto"
    assert op.algo != "auto" and op.algo in mcoll.algorithms("allreduce")
    algo, kw = runtime.resolve_algo(topo, "allreduce", "auto", z)
    assert op.algo == algo and op.chunks == kw.get("chunks", 1)


def test_persistent_depth_validation():
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    with pytest.raises(ValueError, match="depth"):
        comm.allreduce_init(shape=(1, 8), dtype=jnp.float32,
                            algo="pip_mcoll", depth=0)


def test_persistent_donate_is_a_distinct_program():
    """donate=True compiles a separate executable (input aliasing differs)
    but produces identical results."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    z = jnp.ones((1, 32), jnp.float32)
    op = comm.allreduce_init(z, algo="pip_mcoll")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # CPU may ignore donation
        opd = comm.allreduce_init(z, algo="pip_mcoll", donate=True)
        want = np.asarray(op.start(z).wait())
        got = np.asarray(opd.start(jnp.ones((1, 32), jnp.float32)).wait())
    np.testing.assert_array_equal(got, want)
    assert runtime.cache_stats().exec_misses == 2


def test_persistent_carry_roundtrip_and_arg_pairing():
    """A carry op's wait() returns (result, new_state) matching the
    runtime's carry-threaded program, and start() enforces the carry-arg
    pairing both ways (carry op without state / plain op with state)."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((1, 96)), jnp.float32)
    e0 = jnp.asarray(rng.standard_normal((1, 96)), jnp.float32)
    op = comm.allreduce_init(x, algo="pip_mcoll", codec="int8_block",
                             carry=True)
    assert op.carry and op.codec == "int8_block"
    y, e1 = op.start(x, carry=e0).wait()
    fn = runtime.build(mesh, topo, "allreduce", "pip_mcoll", carry=True,
                       codec="int8_block")
    ry, re1 = fn(x, e0)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ry))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(re1))
    # threading the returned state back in is a valid (and the intended)
    # next start; the op stays reusable
    y2, _ = op.start(x, carry=e1).wait()
    assert np.isfinite(np.asarray(y2)).all()
    with pytest.raises(ValueError, match="requires carry=state"):
        op.start(x)
    plain = comm.allreduce_init(x, algo="pip_mcoll")
    with pytest.raises(ValueError, match="does not take a carry"):
        plain.start(x, carry=e0)
    with pytest.raises(ValueError, match="carry"):
        op.start(x, carry=jnp.zeros((1, 8), jnp.float32))  # wrong spec


def test_persistent_carry_needs_err_capable_algorithm():
    """carry=True is the error-feedback hookup: only algorithms with an
    err state operand (the pip family) compile it; xla does not."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    assert runtime.supports_carry("allreduce", "pip_mcoll")
    assert runtime.supports_carry("allreduce", "pip_pipeline")
    assert not runtime.supports_carry("allreduce", "xla")
    with pytest.raises(ValueError, match="carry"):
        comm.allreduce_init(shape=(1, 8), dtype=jnp.float32, algo="xla",
                            carry=True)
    with pytest.raises(ValueError, match="only supported on allreduce"):
        PlanSpec("broadcast", carry=True)


def test_persistent_release_semantics():
    """release() retires the op from the live-op count (idempotently) and
    makes any further start() raise; re-init of the same spec is an
    exec-cache hit, not a recompile."""
    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)
    runtime.clear_cache()
    z = jnp.ones((1, 48), jnp.float32)
    base = comm_mod.live_persistent_ops()
    op = comm.allreduce_init(z, algo="pip_mcoll")
    assert comm_mod.live_persistent_ops() == base + 1
    assert not op.released
    op.release()
    assert op.released
    assert comm_mod.live_persistent_ops() == base
    op.release()  # idempotent: no double-decrement
    assert comm_mod.live_persistent_ops() == base
    with pytest.raises(RuntimeError, match="released"):
        op.start(z)
    misses = runtime.cache_stats().exec_misses
    op2 = comm.allreduce_init(z, algo="pip_mcoll")
    assert runtime.cache_stats().exec_misses == misses  # cache hit
    np.testing.assert_array_equal(np.asarray(op2(z)), np.asarray(z))
    op2.release()


def test_overlapped_sync_releases_ops_on_plan_rebind():
    """Rebind hygiene across budget-schedule plan crossings: every rebuild
    of OverlappedGradSync's bucket ops releases the ops it replaces, so the
    process-wide live-op count stays flat however many times the schedule
    crosses a plan boundary. (The resolver is monkeypatched to alternate
    plans deterministically — on a world-1 topology the real cost model
    resolves every budget to the same lossless plan, which would make the
    crossing a no-op; the 8-device flatness check with the real resolver
    lives in tests/checks/manual_step_check.py.)"""
    from repro.train import manual_step

    mesh, topo = _mesh_topo()
    comm = Communicator(mesh, topo)

    def fake_resolve(topo_, nbytes, dtype, algo, chunks, codec, budget):
        if budget > 0.0:
            return "pip_mcoll", {"codec": "int8_block"}
        return "pip_mcoll", {}

    orig = manual_step._resolve_plan
    manual_step._resolve_plan = fake_resolve
    try:
        sched = lambda step: 0.05 if (step // 2) % 2 else 0.0
        gs = manual_step.OverlappedGradSync(
            comm, [(0, 32), (32, 96)], metric_len=4, algo="pip_mcoll",
            error_budget=sched)
        gs.ensure_ops(0)
        base = comm_mod.live_persistent_ops()
        assert gs.plans() == ["pip_mcoll", "pip_mcoll"]
        assert [op.carry for op in gs._ops] == [False, False]
        payloads = [jnp.ones((1, n), jnp.float32) for _, n in gs.slices]
        mvec = jnp.zeros((1, 4), jnp.float32)
        for step in range(12):
            gs.ensure_ops(step)
            # every crossing rebuilds, none leaks: live count never grows
            assert comm_mod.live_persistent_ops() == base
            synced, _ = gs.sync(payloads, mvec, overlap=bool(step % 2))
            assert all(np.isfinite(np.asarray(s)).all() for s in synced)
        assert gs.rebuilds == 5  # budget crossed a plan boundary 5 times
        assert gs.plans() == ["pip_mcoll@int8_block"] * 2
        assert all(op.carry for op in gs._ops)
        assert all(e is not None for e in gs.errs)
    finally:
        manual_step._resolve_plan = orig


# ---------------------------------------------------------------------------
# regression grep: the retired free-function shims stay retired
# ---------------------------------------------------------------------------


def test_retired_shims_have_no_call_sites():
    """Like the PR-1 shard_map grep: runtime.collective and
    mcoll.collective_fn were deleted after the Communicator migration —
    no code anywhere in the repo may reference them again (new call sites
    go through repro.core.comm.Communicator or runtime.build)."""
    pattern = re.compile(
        r"runtime\.collective\s*\(|"
        r"from\s+repro\.core\.runtime\s+import\s+.*\bcollective\b|"
        r"\bcollective_fn\b")
    allowed = {pathlib.Path(__file__).resolve()}
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for path in sorted((REPO / sub).rglob("*.py")):
            if path.resolve() in allowed:
                continue
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if pattern.search(line):
                    offenders.append(
                        f"{path.relative_to(REPO)}:{i}: {line.strip()}")
    assert not offenders, (
        "references to retired shims (runtime.collective / "
        "mcoll.collective_fn); use Communicator methods or runtime.build:\n"
        + "\n".join(offenders))
    assert not hasattr(runtime, "collective")
    assert not hasattr(mcoll, "collective_fn")


# ---------------------------------------------------------------------------
# PlanSpec normalization (unit level; cache-entry assertions live in
# test_runtime.py::test_exec_cache_kwargs_normalization_single_entry)
# ---------------------------------------------------------------------------


def test_plan_spec_kwargs_drop_unpinned_knobs():
    assert PlanSpec("allreduce").kwargs() == {}
    assert PlanSpec("allreduce", chunks=None, codec=None).kwargs() == {}
    assert PlanSpec("allreduce", chunks=4).kwargs() == {"chunks": 4}
    assert PlanSpec("allreduce", codec="none").kwargs() == {"codec": "none"}
    assert PlanSpec("allreduce", chunk_bytes=1024).kwargs() == \
        {"chunk_bytes": 1024}


def test_plan_spec_normalized_resolution_is_single_plan():
    """Every spelling of the default plan resolves to identical normalized
    kwargs — the exec-cache key material."""
    topo = Topology(1, 1)
    z = jnp.ones((1, 64), jnp.float32)
    resolved = set()
    for spec in (PlanSpec("allreduce", "pip_pipeline"),
                 PlanSpec("allreduce", "pip_pipeline", chunks=1),
                 PlanSpec("allreduce", "pip_pipeline", chunks=None),
                 PlanSpec("allreduce", "pip_pipeline", codec="none"),
                 PlanSpec("allreduce", "pip_pipeline", codec=None)):
        algo, kw = runtime.resolve_algo(topo, spec.collective, spec.algo, z,
                                        spec.kwargs())
        resolved.add((algo, tuple(sorted(kw.items()))))
    assert len(resolved) == 1, resolved

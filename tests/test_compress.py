"""Codec registry units: round-trip bounds, edge cases (non-block-divisible
sizes, bf16 inputs, all-zero blocks), error-feedback properties over many
iterations, budget gating, and the optim re-export."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import compress

LOSSY = compress.lossy()


# ---------------------------------------------------------------------------
# registry + metadata
# ---------------------------------------------------------------------------


def test_registry_names_and_order():
    names = compress.codecs()
    assert names[0] == "none"
    assert {"int8_block", "int4_block", "fp8_sim", "topk",
            "zlib_sim"} <= set(names)
    # zlib_sim is the lossless integer packer — not in the lossy set
    assert set(LOSSY) == set(names) - {"none", "zlib_sim"}


def test_meta_sanity():
    assert compress.meta("none").lossless
    assert compress.meta("none").error_bound == 0.0
    for name in LOSSY:
        m = compress.meta(name)
        assert m.wire_ratio > 1.0, name
        assert 0 < m.error_bound <= 1.0, name
        assert not m.lossless
    # documented bound ordering: int8 tighter than fp8 tighter than topk
    assert (compress.meta("int8_block").error_bound
            < compress.meta("fp8_sim").error_bound
            < compress.meta("topk").error_bound)


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown codec"):
        compress.codec("zstd")


def test_for_budget_gating():
    assert compress.for_budget(0.0) == ("none",)
    b_int8 = compress.meta("int8_block").error_bound
    assert set(compress.for_budget(b_int8)) == {"none", "int8_block"}
    assert set(compress.for_budget(0.07)) == {"none", "int8_block",
                                              "fp8_sim"}
    # float payloads never see the integer-only packer
    assert set(compress.for_budget(1.0)) == \
        set(compress.codecs()) - {"zlib_sim"}
    # integer payloads: lossless packer admissible on non-reducing
    # collectives even at budget 0; lossy codecs never admissible
    assert set(compress.for_budget(0.0, "broadcast",
                                   integer_payload=True)) == \
        {"none", "zlib_sim"}
    assert set(compress.for_budget(1.0, "allreduce",
                                   integer_payload=True)) == {"none"}


# ---------------------------------------------------------------------------
# round-trip bounds (the stated contract the selector relies on)
# ---------------------------------------------------------------------------


def _roundtrip_err(name, x2d):
    cd = compress.codec(name)
    back = np.asarray(cd.decode(cd.encode(jnp.asarray(x2d)), x2d.shape[1]))
    assert back.shape == x2d.shape
    return np.abs(back - np.asarray(x2d, np.float32))


@pytest.mark.parametrize("name", ("int8_block", "int4_block", "fp8_sim"))
@given(scale=st.floats(1e-4, 1e3), length=st.integers(1, 2000),
       seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_roundtrip_error_bound(name, scale, length, seed):
    """Elementwise round-trip error <= stated bound * slice max, including
    non-BLOCK-divisible lengths."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                     (3, length))) * scale
    err = _roundtrip_err(name, x)
    bound = compress.meta(name).error_bound
    tol = bound * np.abs(x).max(axis=1, keepdims=True) + 1e-12
    assert (err <= tol + 1e-7 * scale).all(), (name, err.max())


def test_topk_roundtrip_keeps_largest_and_bounds_rest():
    # distinct magnitudes (no |x| ties), alternating signs; L=160 -> k=10
    x = (np.linspace(0.1, 4.0, 160)
         * np.where(np.arange(160) % 2 == 0, 1.0, -1.0)
         )[None, :].astype(np.float32)
    cd = compress.codec("topk")
    comp = cd.encode(jnp.asarray(x))
    back = np.asarray(cd.decode(comp, x.shape[1]))
    # the largest-magnitude k elements survive exactly
    order = np.argsort(-np.abs(x[0]))
    np.testing.assert_array_equal(back[0, order[:10]], x[0, order[:10]])
    # dropped elements error by their own value, bounded by the slice max
    err = np.abs(back - x)
    assert err.max() <= compress.meta("topk").error_bound * np.abs(x).max()


def test_none_codec_identity():
    x = jnp.arange(12.0).reshape(2, 6)
    cd = compress.codec("none")
    np.testing.assert_array_equal(
        np.asarray(cd.decode(cd.encode(x), 6)), np.asarray(x))


def test_zlib_sim_lossless_roundtrip_small_range_integers():
    """Bit-width packing is exactly lossless while each slice's value
    range stays under 2^16 (the documented domain: token ids, expert
    indices) — including negative bases and non-zero minima."""
    m = compress.meta("zlib_sim")
    assert m.lossless and m.error_bound == 0.0 and m.integer_only
    assert m.wire_ratio > 1.9
    cd = compress.codec("zlib_sim")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-40_000, -40_000 + 65_535, (3, 777)),
                    jnp.int32)
    back = cd.decode(cd.encode(x), 777)
    assert back.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    # large magnitudes survive as long as the per-slice RANGE is small
    big = jnp.asarray(rng.integers(2 ** 28, 2 ** 28 + 1000, (2, 64)),
                      jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(cd.decode(cd.encode(big), 64)), np.asarray(big))


def test_zlib_sim_wire_is_uint16_offsets():
    cd = compress.codec("zlib_sim")
    comp = cd.encode(jnp.asarray([[5, 7, 5, 70000]], jnp.int32))
    assert comp["lo"].dtype == jnp.uint16
    assert comp["base"].dtype == jnp.int32
    # wire accounting is MEASURED (entropy/run-length on the packed
    # offsets), never exceeding the raw uint16 packing + per-slice base
    assert 0 < cd.wire_bytes(comp) <= 4 * 2 + 4


def test_zlib_sim_wire_bytes_are_measured_not_assumed():
    cd = compress.codec("zlib_sim")
    # a constant payload is one long run: the measured estimate collapses
    # far below the raw packing, the way a real byte compressor would
    const = cd.encode(jnp.full((1, 4096), 17, jnp.int32))
    assert cd.wire_bytes(const) < 0.05 * (4096 * 2 + 4)
    # a full-range payload has ~8-bit bytes: the estimate stays near raw
    rng = np.random.default_rng(5)
    wide = cd.encode(jnp.asarray(rng.integers(0, 65_536, (1, 4096)),
                                 jnp.int32))
    assert cd.wire_bytes(wide) > 0.85 * (4096 * 2)
    # the estimate is byte-count monotone in what it claims: never more
    # than the raw packed stream
    assert cd.wire_bytes(wide) <= 4096 * 2 + 4


def test_zlib_sim_refresh_ratio_measures_sample():
    cd = compress.codec("zlib_sim")
    before = cd.meta.wire_ratio
    assert before > 1.9  # seeded from the canonical token-id sample
    try:
        # a constant payload measures a huge ratio
        r = cd.refresh_ratio(jnp.full((2, 2048), 9, jnp.int32))
        assert r == cd.meta.wire_ratio and r > 20.0
    finally:
        cd.refresh_ratio(
            jnp.asarray((np.arange(4096) * 2654435761) % 50257,
                        jnp.int32).reshape(1, -1))
    assert abs(cd.meta.wire_ratio - before) < 0.2


@pytest.mark.parametrize("name", LOSSY)
def test_all_zero_blocks_no_nan(name):
    """All-zero payloads (and zero blocks inside non-zero payloads) must
    round-trip to exact zeros — no divide-by-zero in the scales."""
    cd = compress.codec(name)
    z = jnp.zeros((2, compress.BLOCK * 2 + 7))
    back = np.asarray(cd.decode(cd.encode(z), z.shape[1]))
    assert np.isfinite(back).all()
    np.testing.assert_array_equal(back, np.zeros_like(back))
    # one zero block among non-zero blocks
    x = jnp.zeros((1, compress.BLOCK * 2)).at[0, :compress.BLOCK].set(1.0)
    back = np.asarray(cd.decode(cd.encode(x), x.shape[1]))
    assert np.isfinite(back).all()
    np.testing.assert_array_equal(back[0, compress.BLOCK:], 0.0)


@pytest.mark.parametrize("name", LOSSY)
def test_bf16_inputs(name):
    """Codecs accept bf16 slices (cast to f32 internally) and stay within
    the stated bound of the bf16 values."""
    x = (jax.random.normal(jax.random.PRNGKey(3), (2, 333))
         .astype(jnp.bfloat16))
    cd = compress.codec(name)
    back = np.asarray(cd.decode(cd.encode(x), 333))
    xf = np.asarray(x, np.float32)
    bound = compress.meta(name).error_bound
    assert np.abs(back - xf).max() <= bound * np.abs(xf).max() + 1e-6


@pytest.mark.parametrize("name", LOSSY)
def test_wire_bytes_match_declared_ratio(name):
    """Actual wire bytes of the encoded form track meta.wire_ratio (within
    padding slack on a block-aligned payload)."""
    n = compress.BLOCK * 16
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n))
    cd = compress.codec(name)
    actual = 4.0 * n / cd.wire_bytes(cd.encode(x))
    assert actual >= cd.meta.wire_ratio * 0.9, (name, actual)


# ---------------------------------------------------------------------------
# error feedback: the round-trip bound holds over 100 iterations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("int8_block", "fp8_sim"))
def test_error_feedback_bound_over_100_iterations(name):
    """With feedback, the accumulated decoded stream lags the true
    accumulated signal by at most ~one step's residual — for every step of
    100 (EF: sum_decoded(T) = T*g + e_0 - e_T, |e_T| bounded)."""
    cd = compress.codec(name)
    g = jax.random.normal(jax.random.PRNGKey(7), (2, 500)) * 1e-3
    gmax = float(jnp.abs(g).max())
    bound = cd.meta.error_bound
    lag_cap = bound / (1.0 - bound) * gmax * 1.05 + 1e-12
    err = jnp.zeros_like(g)
    acc = np.zeros(g.shape, np.float32)
    step = jax.jit(cd.encode_with_feedback)
    for t in range(1, 101):
        comp, err = step(g, err)
        acc += np.asarray(cd.decode(comp, g.shape[1]))
        lag = np.abs(acc - np.asarray(g) * t).max()
        assert lag <= lag_cap, (name, t, lag, lag_cap)
        assert float(jnp.abs(err).max()) <= lag_cap, (name, t)


def test_error_feedback_beats_no_feedback_topk():
    """Top-k has no useful per-step bound, but feedback must still keep the
    accumulated stream closer than feedback-free top-k (dropped coordinates
    accumulate residual until they win a round)."""
    cd = compress.codec("topk")
    g = jax.random.normal(jax.random.PRNGKey(11), (1, 320)) * 1e-2
    err = jnp.zeros_like(g)
    acc_fb = np.zeros(g.shape, np.float32)
    acc_nofb = np.zeros(g.shape, np.float32)
    for _ in range(100):
        comp, err = cd.encode_with_feedback(g, err)
        acc_fb += np.asarray(cd.decode(comp, g.shape[1]))
        acc_nofb += np.asarray(cd.decode(cd.encode(g), g.shape[1]))
    true = np.asarray(g) * 100
    assert np.abs(acc_fb - true).max() < np.abs(acc_nofb - true).max()


# ---------------------------------------------------------------------------
# collective tolerance helper + optim re-export
# ---------------------------------------------------------------------------


def test_collective_tolerance_shapes_and_monotonicity():
    assert compress.collective_tolerance("none", "allreduce", 8, 1.0) == 0.0
    t1 = compress.collective_tolerance("int8_block", "allgather", 8, 1.0)
    t2 = compress.collective_tolerance("int8_block", "reduce_scatter", 8, 1.0)
    t3 = compress.collective_tolerance("int8_block", "allreduce", 8, 1.0)
    assert 0 < t1 < t2 < t3
    # root-encodes-once: broadcast/scatter pay exactly one round trip
    assert compress.collective_tolerance("int8_block", "broadcast",
                                         8, 1.0) == t1
    assert compress.collective_tolerance("int8_block", "scatter",
                                         8, 1.0) == t1
    with pytest.raises(ValueError, match="no compressed execution"):
        compress.collective_tolerance("int8_block", "gossip", 8, 1.0)


# ---------------------------------------------------------------------------
# fused-lowering capability flag + routing toggle
# ---------------------------------------------------------------------------


def test_fused_codecs_advertise_lowerings():
    fused = compress.fused_codecs()
    assert "int8_block" in fused and "int4_block" in fused
    for name in fused:
        m = compress.meta(name)
        assert m.fused
        assert m.fused_flops_per_elem is not None
        # fusion removes passes; it must never be priced as MORE work
        assert m.fused_flops_per_elem < m.flops_per_elem, name
    for name in set(compress.codecs()) - set(fused):
        assert not compress.meta(name).fused, name


def test_effective_flops_follow_the_toggle():
    assert compress.fused_enabled()
    name = "int8_block"
    m = compress.meta(name)
    assert compress.effective_flops_per_elem(name) == m.fused_flops_per_elem
    with compress.jnp_reference_paths():
        assert not compress.fused_enabled()
        assert compress.effective_flops_per_elem(name) == m.flops_per_elem
        # nesting restores correctly
        with compress.jnp_reference_paths():
            pass
        assert not compress.fused_enabled()
    assert compress.fused_enabled()
    # non-fused codecs are toggle-invariant
    assert compress.effective_flops_per_elem("topk") == \
        compress.meta("topk").flops_per_elem


def test_fused_and_jnp_feedback_agree_bitwise_on_wire():
    """The routed encode_with_feedback must produce the identical wire form
    either way (both under jit — XLA's fused scale arithmetic differs from
    eager by an ulp on some blocks)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 700))
    err = jnp.zeros_like(x)
    for name in compress.fused_codecs():
        cd = compress.codec(name)
        comp_f, res_f = jax.jit(cd.encode_with_feedback)(x, err)
        with compress.jnp_reference_paths():
            comp_j, res_j = jax.jit(cd.encode_with_feedback)(x, err)
        for leaf in comp_j:
            np.testing.assert_array_equal(np.asarray(comp_f[leaf]),
                                          np.asarray(comp_j[leaf]),
                                          err_msg=f"{name}/{leaf}")
        np.testing.assert_allclose(np.asarray(res_f), np.asarray(res_j),
                                   rtol=0, atol=1e-6)


def test_int4_block_packs_two_per_byte():
    cd = compress.codec("int4_block")
    x = jax.random.normal(jax.random.PRNGKey(1), (3, compress.BLOCK * 2))
    comp = cd.encode(x)
    assert comp["q"].dtype == jnp.uint8
    assert comp["q"].shape == (3, 2, compress.BLOCK // 2)
    # stated bound ordering: coarser than int8, and the declared ratio is
    # about twice int8's (half the payload bytes, same per-block scale)
    assert compress.meta("int8_block").error_bound \
        < compress.meta("int4_block").error_bound
    assert compress.meta("int4_block").wire_ratio \
        > 1.9 * compress.meta("int8_block").wire_ratio


def test_optim_reexports_core_codec_math():
    """No duplicate quantize/dequantize implementations: optim.compress is
    a re-export of the core codec math."""
    from repro.optim import compress as optim_compress
    assert optim_compress.quantize is compress.quantize
    assert optim_compress.dequantize is compress.dequantize
    assert optim_compress.compress_tree is compress.compress_tree
    assert optim_compress.BLOCK == compress.BLOCK
    assert not hasattr(optim_compress, "compressed_allreduce"), \
        "bespoke compressed_allreduce must be gone (use the subsystem)"

"""Run a check script in a subprocess with a forced host device count.

Multi-device CPU tests must set XLA_FLAGS before jax initializes; doing so
in-process would leak 512 fake devices into every other test (the system
requires smoke tests and benches to see exactly 1 device). Subprocesses keep
the device-count containment airtight.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
CHECKS = pathlib.Path(__file__).resolve().parent / "checks"


def run_check(script: str, ndev: int, *args: str, timeout: int = 900) -> str:
    """Execute tests/checks/<script> with `ndev` fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={ndev} "
                        + env.get("XLA_FLAGS", "").replace(
                            env.get("_REPRO_DEVFLAG", "\x00"), ""))
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    proc = subprocess.run(
        [sys.executable, str(CHECKS / script), *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} {args} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
